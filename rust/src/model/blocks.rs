//! The shared pre-norm encoder stack: multi-head attention + GELU MLP
//! blocks with hand-derived backward passes.
//!
//! Both model families drive this engine — the LM with a causal mask, the
//! ViT bidirectionally — on activations laid out as `[batch*seq, d_model]`
//! row-major matrices (row `b*s + i` is position `i` of batch element
//! `b`). One block computes, exactly like `layers.py`:
//!
//! ```text
//! x = x + Wo·attn(rms_norm(x, ln1) · {Wq,Wk,Wv})     (pre-norm attention)
//! x = x + gelu(rms_norm(x, ln2) · W1) · W2           (pre-norm GELU MLP)
//! ```
//!
//! The backward pass replays the chain in reverse from cached forward
//! intermediates; the elementwise/softmax/norm VJPs come from
//! `tensor::ops` where each is finite-difference-checked, and the whole
//! stack is FD-checked again end-to-end in `model::transformer` tests.
//!
//! Attention is matmul-shaped end to end: QKᵀ, the masked softmax (and
//! its VJP), and the context/cotangent accumulations all run on the
//! batched panel primitives of `tensor::batched`, which pack the
//! head-strided views into contiguous panels for the cache-blocked
//! kernels. The pre-refactor scalar nests survive in [`reference`] as
//! the bit-exactness oracle and microbench baseline.
//!
//! # Fused QKV
//!
//! Since PR 5 the three per-layer input projections run as ONE GEMM:
//! `wq|wk|wv` are packed into a `[d, 3d]` panel
//! ([`Matrix::concat_cols`]), the forward computes `qkv = n1 · Wqkv`
//! and slices the thirds straight into head panels
//! (`gather_heads_at`), and the backward packs `dq|dk|dv` into one
//! `[b*s, 3d]` cotangent so `dWqkv = n1ᵀ · dqkv` (split back into the
//! three parameter gradients) and `dn1 = dqkv · Wqkvᵀ` are one GEMM
//! each instead of three. Column blocks of a GEMM contract
//! independently, so the fused forward and the three parameter
//! gradients are **bit-identical** to the unfused products (the
//! [`reference::qkv_unfused`] oracle asserts this exactly); only `dn1`
//! sums its 3d contraction terms in one ascending pass instead of as
//! three partial sums added afterwards — same math, one float
//! re-association, covered by the finite-difference stack tests and an
//! allclose oracle comparison. The packed `Wqkv` panel is built once
//! per layer per forward and cached in [`LayerCache`] (parameters
//! mutate every optimizer step, so caching across steps would need
//! invalidation machinery for an O(d²)-vs-O(b·s·d²) saving).

use super::{add_grad, pget, ParamSet};
use crate::tensor::{
    attention_backward_fused, batched_matmul, batched_matmul_nt,
    batched_matmul_tn, gather_heads, gather_heads_at, gelu, gelu_grad,
    rms_norm_rows, rms_norm_rows_vjp, scatter_heads, scatter_heads_at,
    softmax_rows_masked, softmax_rows_vjp_batched, BatchedMatrix, KernelDriver,
    Matrix, Parallelism,
};

/// Dimensions of the encoder stack shared by the LM and ViT configs.
#[derive(Clone, Copy, Debug)]
pub struct BlockDims {
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
}

impl BlockDims {
    pub fn head_dim(&self) -> usize {
        debug_assert_eq!(self.d_model % self.n_heads, 0);
        self.d_model / self.n_heads
    }

    /// (name, shape) of one block's parameters, `layer{l}/...`-prefixed.
    pub fn layer_shapes(&self, l: usize) -> Vec<(String, [usize; 2])> {
        let d = self.d_model;
        let f = self.d_ff;
        [
            ("attn/wq", [d, d]),
            ("attn/wk", [d, d]),
            ("attn/wv", [d, d]),
            ("attn/wo", [d, d]),
            ("ffn/w1", [d, f]),
            ("ffn/w2", [f, d]),
            ("ln1/scale", [1, d]),
            ("ln2/scale", [1, d]),
        ]
        .into_iter()
        .map(|(suffix, sh)| (format!("layer{l}/{suffix}"), sh))
        .collect()
    }
}

/// Forward intermediates of one block, kept for the backward pass. The
/// q/k/v projections are cached in their PACKED `[b*h, s, dh]` panel
/// form (same bytes as the flat matrices) so the backward contractions
/// reuse them without re-gathering, and the fused `[d, 3d]` `wq|wk|wv`
/// panel is kept so the backward's `dn1 = dqkv · Wqkvᵀ` GEMM never
/// re-packs the parameters.
pub(crate) struct LayerCache {
    x_in: Matrix,
    n1: Matrix,
    /// the packed `wq|wk|wv` projection panel this forward used
    wqkv: Matrix,
    qh: BatchedMatrix,
    kh: BatchedMatrix,
    vh: BatchedMatrix,
    /// attention probabilities, one `[s, s]` panel per (batch, head)
    probs: BatchedMatrix,
    ctx: Matrix,
    x_mid: Matrix,
    n2: Matrix,
    h1: Matrix,
}

/// Pack layer `l`'s `wq|wk|wv` into the fused `[d, 3d]` projection panel.
fn pack_wqkv(params: &ParamSet, l: usize) -> Matrix {
    Matrix::concat_cols(&[
        pget(params, &format!("layer{l}/attn/wq")),
        pget(params, &format!("layer{l}/attn/wk")),
        pget(params, &format!("layer{l}/attn/wv")),
    ])
}

/// Run the whole block stack. Returns the output activations (input to
/// the caller's final norm) and the per-layer caches for
/// [`stack_backward`].
pub(crate) fn stack_forward(
    params: &ParamSet,
    dims: BlockDims,
    x0: Matrix,
    b: usize,
    s: usize,
    causal: bool,
) -> (Matrix, Vec<LayerCache>) {
    debug_assert_eq!(x0.shape(), (b * s, dims.d_model));
    let mut x = x0;
    let mut caches = Vec::with_capacity(dims.n_layers);
    let h = dims.n_heads;
    let dh = dims.head_dim();
    let d = dims.d_model;
    for l in 0..dims.n_layers {
        let p = |suffix: &str| format!("layer{l}/{suffix}");
        let n1 = rms_norm_rows(&x, pget(params, &p("ln1/scale")));
        // fused QKV: one [b*s, d] x [d, 3d] GEMM; the thirds' column
        // blocks are bit-identical to the three separate projections
        let wqkv = pack_wqkv(params, l);
        let qkv = n1.matmul(&wqkv);
        let qh = gather_heads_at(&qkv, b, s, h, dh, 0);
        let kh = gather_heads_at(&qkv, b, s, h, dh, d);
        let vh = gather_heads_at(&qkv, b, s, h, dh, 2 * d);
        let (ctx, probs) = attention_forward_packed(&qh, &kh, &vh, dims, b, s, causal);
        let attn_out = ctx.matmul(pget(params, &p("attn/wo")));
        let x_mid = &x + &attn_out;
        let n2 = rms_norm_rows(&x_mid, pget(params, &p("ln2/scale")));
        let h1 = n2.matmul(pget(params, &p("ffn/w1")));
        let ff = gelu(&h1).matmul(pget(params, &p("ffn/w2")));
        let x_out = &x_mid + &ff;
        caches.push(LayerCache {
            x_in: x, n1, wqkv, qh, kh, vh, probs, ctx, x_mid, n2, h1,
        });
        x = x_out;
    }
    (x, caches)
}

/// Backpropagate `dx` (cotangent of the stack output) through every
/// block, accumulating parameter gradients into `grads` and returning the
/// cotangent of the stack input `x0`.
#[allow(clippy::too_many_arguments)]
pub(crate) fn stack_backward(
    params: &ParamSet,
    dims: BlockDims,
    caches: Vec<LayerCache>,
    mut dx: Matrix,
    b: usize,
    s: usize,
    // the mask needs no replay: it lives in the cached probabilities
    _causal: bool,
    grads: &mut ParamSet,
) -> Matrix {
    for (l, cache) in caches.into_iter().enumerate().rev() {
        let p = |suffix: &str| format!("layer{l}/{suffix}");
        // MLP branch: x_out = x_mid + gelu(n2 W1) W2, dff = dx
        let a = gelu(&cache.h1);
        add_grad(grads, &p("ffn/w2"), a.matmul_tn(&dx));
        let da = dx.matmul_nt(pget(params, &p("ffn/w2")));
        let dh1 = da.hadamard(&gelu_grad(&cache.h1));
        add_grad(grads, &p("ffn/w1"), cache.n2.matmul_tn(&dh1));
        let dn2 = dh1.matmul_nt(pget(params, &p("ffn/w1")));
        let (dx_mid_norm, dln2) =
            rms_norm_rows_vjp(&cache.x_mid, pget(params, &p("ln2/scale")), &dn2);
        add_grad(grads, &p("ln2/scale"), dln2);
        // x_mid feeds both the residual and the norm path
        let mut dx_mid = &dx + &dx_mid_norm;

        // attention branch: d attn_out = dx_mid (residual of x_mid)
        add_grad(grads, &p("attn/wo"), cache.ctx.matmul_tn(&dx_mid));
        let dctx = dx_mid.matmul_nt(pget(params, &p("attn/wo")));
        let (dqh, dkh, dvh) = attention_backward_panels(
            &cache.qh, &cache.kh, &cache.vh, &cache.probs, &dctx, dims, b, s,
        );
        // fused QKV backward: pack dq|dk|dv into one [b*s, 3d] cotangent;
        // dWqkv = n1ᵀ·dqkv splits into the three parameter gradients
        // (bit-identical to the unfused products — independent column
        // blocks), and dn1 = dqkv·Wqkvᵀ is one GEMM over all 3d terms
        let d = dims.d_model;
        let mut dqkv = Matrix::zeros(b * s, 3 * d);
        scatter_heads_at(&mut dqkv, &dqh, b, s, dims.n_heads, dims.head_dim(), 0);
        scatter_heads_at(&mut dqkv, &dkh, b, s, dims.n_heads, dims.head_dim(), d);
        scatter_heads_at(&mut dqkv, &dvh, b, s, dims.n_heads, dims.head_dim(), 2 * d);
        let dwqkv = cache.n1.matmul_tn(&dqkv);
        let mut dw = dwqkv.split_cols(&[d, d, d]);
        add_grad(grads, &p("attn/wv"), dw.pop().expect("dwv"));
        add_grad(grads, &p("attn/wk"), dw.pop().expect("dwk"));
        add_grad(grads, &p("attn/wq"), dw.pop().expect("dwq"));
        let dn1 = dqkv.matmul_nt(&cache.wqkv);
        let (dx_in_norm, dln1) =
            rms_norm_rows_vjp(&cache.x_in, pget(params, &p("ln1/scale")), &dn1);
        add_grad(grads, &p("ln1/scale"), dln1);
        dx_mid.add_scaled_inplace(&dx_in_norm, 1.0);
        dx = dx_mid;
    }
    dx
}

/// Multi-head scaled-dot-product attention on `[b*s, d]` activations,
/// phrased entirely as batched GEMMs: the head-strided q/k/v views are
/// packed into contiguous `[b*h, s, dh]` panels, QKᵀ and probs·V run on
/// the cache-blocked kernels, and the causal mask is applied inside the
/// masked softmax. Returns the context (pre-`Wo`) and the probability
/// panels the backward pass needs.
///
/// Bit-identical to the retained scalar path ([`reference`]) for every
/// `Parallelism` setting — the `attention_matches_scalar_reference` test
/// compares them exactly.
pub fn attention_forward(
    q: &Matrix,
    k: &Matrix,
    v: &Matrix,
    dims: BlockDims,
    b: usize,
    s: usize,
    causal: bool,
) -> (Matrix, BatchedMatrix) {
    let h = dims.n_heads;
    let dh = dims.head_dim();
    let qh = gather_heads(q, b, s, h, dh);
    let kh = gather_heads(k, b, s, h, dh);
    let vh = gather_heads(v, b, s, h, dh);
    attention_forward_packed(&qh, &kh, &vh, dims, b, s, causal)
}

/// [`attention_forward`] on already-packed `[b*h, s, dh]` q/k/v panels —
/// the stack keeps the panels in its [`LayerCache`], so forward and
/// backward each pack exactly once.
pub(crate) fn attention_forward_packed(
    qh: &BatchedMatrix,
    kh: &BatchedMatrix,
    vh: &BatchedMatrix,
    dims: BlockDims,
    b: usize,
    s: usize,
    causal: bool,
) -> (Matrix, BatchedMatrix) {
    let h = dims.n_heads;
    let dh = dims.head_dim();
    let scale = 1.0 / (dh as f32).sqrt();
    let mut probs = batched_matmul_nt(qh, kh, scale);
    softmax_rows_masked(&mut probs, causal);
    let ctxh = batched_matmul(&probs, vh);
    (scatter_heads(&ctxh, b, s, h, dh), probs)
}

/// Backward of [`attention_forward`]: cotangents of q, k, v given the
/// context cotangent — the same four contractions (dprobs = dctx·Vᵀ,
/// dV = probsᵀ·dctx, dQ = dS·K, dK = dSᵀ·Q) as batched GEMMs, with the
/// softmax VJP in between. Masked targets carry zero probability, so
/// their score gradients vanish without special-casing.
#[allow(clippy::too_many_arguments)]
pub fn attention_backward(
    q: &Matrix,
    k: &Matrix,
    v: &Matrix,
    probs: &BatchedMatrix,
    dctx: &Matrix,
    dims: BlockDims,
    b: usize,
    s: usize,
) -> (Matrix, Matrix, Matrix) {
    let h = dims.n_heads;
    let dh = dims.head_dim();
    let qh = gather_heads(q, b, s, h, dh);
    let kh = gather_heads(k, b, s, h, dh);
    let vh = gather_heads(v, b, s, h, dh);
    attention_backward_packed(&qh, &kh, &vh, probs, dctx, dims, b, s)
}

/// [`attention_backward`] on the cached packed panels.
#[allow(clippy::too_many_arguments)]
pub(crate) fn attention_backward_packed(
    qh: &BatchedMatrix,
    kh: &BatchedMatrix,
    vh: &BatchedMatrix,
    probs: &BatchedMatrix,
    dctx: &Matrix,
    dims: BlockDims,
    b: usize,
    s: usize,
) -> (Matrix, Matrix, Matrix) {
    let h = dims.n_heads;
    let dh = dims.head_dim();
    let (dqh, dkh, dvh) =
        attention_backward_panels(qh, kh, vh, probs, dctx, dims, b, s);
    (
        scatter_heads(&dqh, b, s, h, dh),
        scatter_heads(&dkh, b, s, h, dh),
        scatter_heads(&dvh, b, s, h, dh),
    )
}

/// The attention cotangents in PANEL form (`[b*h, s, dh]`), before any
/// scatter — the fused-QKV backward scatters all three into one
/// `[b*s, 3d]` matrix instead of three separate ones.
///
/// On the pool driver the four contractions run as ONE pool submission
/// ([`attention_backward_fused`] — one latch instead of four); the scope
/// driver keeps the four-call sequence, which doubles as the fused
/// dispatch's bit-exactness oracle (this module's tests compare them
/// exactly — same band bodies, so identity holds by construction).
#[allow(clippy::too_many_arguments)]
pub(crate) fn attention_backward_panels(
    qh: &BatchedMatrix,
    kh: &BatchedMatrix,
    vh: &BatchedMatrix,
    probs: &BatchedMatrix,
    dctx: &Matrix,
    dims: BlockDims,
    b: usize,
    s: usize,
) -> (BatchedMatrix, BatchedMatrix, BatchedMatrix) {
    let h = dims.n_heads;
    let dh = dims.head_dim();
    let scale = 1.0 / (dh as f32).sqrt();
    let dctxh = gather_heads(dctx, b, s, h, dh);
    match Parallelism::current().driver() {
        KernelDriver::Pool => {
            attention_backward_fused(&dctxh, probs, qh, kh, vh, scale)
        }
        KernelDriver::Scope => {
            attention_backward_panels_unfused(&dctxh, probs, qh, kh, vh, scale)
        }
    }
}

/// The pre-PR-9 four-submission backward attention, retained as the
/// fused dispatch's oracle and the `--runtime scope` baseline path.
pub(crate) fn attention_backward_panels_unfused(
    dctxh: &BatchedMatrix,
    probs: &BatchedMatrix,
    qh: &BatchedMatrix,
    kh: &BatchedMatrix,
    vh: &BatchedMatrix,
    scale: f32,
) -> (BatchedMatrix, BatchedMatrix, BatchedMatrix) {
    let dprobs = batched_matmul_nt(dctxh, vh, 1.0);
    let dvh = batched_matmul_tn(probs, dctxh);
    // fold the score scale into the cotangent ONCE (elementwise, exactly
    // like the scalar path's `g = dscores * scale`) so dQ/dK stay
    // bit-identical to the reference
    let mut dscores = softmax_rows_vjp_batched(probs, &dprobs);
    dscores.scale_inplace(scale);
    let dqh = batched_matmul(&dscores, kh);
    let dkh = batched_matmul_tn(&dscores, qh);
    (dqh, dkh, dvh)
}

/// The pre-refactor scalar attention, retained verbatim as the numerical
/// oracle for the batched path (bit-compared in this module's tests) and
/// as the `benches/micro_kernels.rs` throughput baseline. Not called by
/// any training path.
pub mod reference {
    use super::BlockDims;
    use crate::tensor::{softmax_rows, softmax_rows_vjp, Matrix};

    /// The pre-fusion per-layer input projections, retained as the
    /// fused-QKV oracle. Runs on the NAIVE kernels so it is independent
    /// of both the blocking and the fusion under test.
    ///
    /// Forward and the three parameter gradients are bit-identical to
    /// the fused path (column blocks of a GEMM contract independently);
    /// `dn1` is returned in the pre-fusion association — three partial
    /// sums added afterwards — which the fused single-pass contraction
    /// matches only to rounding (see the module docs).
    pub mod qkv_unfused {
        use crate::tensor::Matrix;

        /// `(q, k, v)` — three separate naive projections.
        pub fn forward(
            n1: &Matrix,
            wq: &Matrix,
            wk: &Matrix,
            wv: &Matrix,
        ) -> (Matrix, Matrix, Matrix) {
            (n1.matmul_naive(wq), n1.matmul_naive(wk), n1.matmul_naive(wv))
        }

        /// `(dwq, dwk, dwv, dn1)` from the projection cotangents.
        #[allow(clippy::too_many_arguments)]
        pub fn backward(
            n1: &Matrix,
            wq: &Matrix,
            wk: &Matrix,
            wv: &Matrix,
            dq: &Matrix,
            dk: &Matrix,
            dv: &Matrix,
        ) -> (Matrix, Matrix, Matrix, Matrix) {
            let dwq = n1.matmul_tn_naive(dq);
            let dwk = n1.matmul_tn_naive(dk);
            let dwv = n1.matmul_tn_naive(dv);
            let mut dn1 = dq.matmul_nt_naive(wq);
            dn1.add_scaled_inplace(&dk.matmul_nt_naive(wk), 1.0);
            dn1.add_scaled_inplace(&dv.matmul_nt_naive(wv), 1.0);
            (dwq, dwk, dwv, dn1)
        }
    }

    /// Score assigned to causally-masked attention targets before the
    /// softmax; exp(-1e30 - max) underflows to exactly 0 probability.
    const MASKED: f32 = -1e30;

    /// Scalar-loop multi-head attention forward (the pre-refactor code).
    pub fn attention_forward(
        q: &Matrix,
        k: &Matrix,
        v: &Matrix,
        dims: BlockDims,
        b: usize,
        s: usize,
        causal: bool,
    ) -> (Matrix, Vec<Matrix>) {
        let d = dims.d_model;
        let h = dims.n_heads;
        let dh = dims.head_dim();
        let scale = 1.0 / (dh as f32).sqrt();
        let mut ctx = Matrix::zeros(b * s, d);
        let mut probs_all = Vec::with_capacity(b * h);
        for bi in 0..b {
            for hi in 0..h {
                let off = hi * dh;
                let mut scores = Matrix::zeros(s, s);
                for i in 0..s {
                    let qrow = q.row(bi * s + i);
                    for j in 0..s {
                        if causal && j > i {
                            *scores.at_mut(i, j) = MASKED;
                            continue;
                        }
                        let krow = k.row(bi * s + j);
                        let mut acc = 0.0f32;
                        for t in 0..dh {
                            acc += qrow[off + t] * krow[off + t];
                        }
                        *scores.at_mut(i, j) = acc * scale;
                    }
                }
                let probs = softmax_rows(&scores);
                for i in 0..s {
                    let prow = probs.row(i);
                    for j in 0..s {
                        let pij = prow[j];
                        let vrow = v.row(bi * s + j);
                        for t in 0..dh {
                            *ctx.at_mut(bi * s + i, off + t) += pij * vrow[off + t];
                        }
                    }
                }
                probs_all.push(probs);
            }
        }
        (ctx, probs_all)
    }

    /// Scalar-loop attention backward (the pre-refactor code).
    #[allow(clippy::too_many_arguments)]
    pub fn attention_backward(
        q: &Matrix,
        k: &Matrix,
        v: &Matrix,
        probs_all: &[Matrix],
        dctx: &Matrix,
        dims: BlockDims,
        b: usize,
        s: usize,
    ) -> (Matrix, Matrix, Matrix) {
        let d = dims.d_model;
        let h = dims.n_heads;
        let dh = dims.head_dim();
        let scale = 1.0 / (dh as f32).sqrt();
        let mut dq = Matrix::zeros(b * s, d);
        let mut dk = Matrix::zeros(b * s, d);
        let mut dv = Matrix::zeros(b * s, d);
        for bi in 0..b {
            for hi in 0..h {
                let off = hi * dh;
                let probs = &probs_all[bi * h + hi];
                // dprobs[i][j] = <dctx[(b,i)], v[(b,j)]> over this head
                let mut dprobs = Matrix::zeros(s, s);
                for i in 0..s {
                    let dcrow = dctx.row(bi * s + i);
                    let prow = probs.row(i);
                    for j in 0..s {
                        let vrow = v.row(bi * s + j);
                        let mut acc = 0.0f32;
                        for t in 0..dh {
                            acc += dcrow[off + t] * vrow[off + t];
                        }
                        *dprobs.at_mut(i, j) = acc;
                    }
                    // dv[(b,j)] += probs[i][j] * dctx[(b,i)]
                    for j in 0..s {
                        let pij = prow[j];
                        for t in 0..dh {
                            *dv.at_mut(bi * s + j, off + t) += pij * dcrow[off + t];
                        }
                    }
                }
                let dscores = softmax_rows_vjp(probs, &dprobs);
                for i in 0..s {
                    let dsrow = dscores.row(i);
                    for j in 0..s {
                        let g = dsrow[j] * scale;
                        let krow = k.row(bi * s + j);
                        let qrow = q.row(bi * s + i);
                        for t in 0..dh {
                            *dq.at_mut(bi * s + i, off + t) += g * krow[off + t];
                            *dk.at_mut(bi * s + j, off + t) += g * qrow[off + t];
                        }
                    }
                }
            }
        }
        (dq, dk, dv)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn dims() -> BlockDims {
        BlockDims { d_model: 8, n_layers: 2, n_heads: 2, d_ff: 16 }
    }

    fn toy_params(dims: BlockDims, seed: u64) -> ParamSet {
        let mut rng = Rng::new(seed);
        let mut params = ParamSet::new();
        for l in 0..dims.n_layers {
            for (name, sh) in dims.layer_shapes(l) {
                let m = if name.ends_with("/scale") {
                    Matrix::from_fn(sh[0], sh[1], |_, _| 1.0)
                } else {
                    Matrix::gaussian(sh[0], sh[1], 1.0 / (sh[0] as f32).sqrt(), &mut rng)
                };
                params.insert(name, m);
            }
        }
        params
    }

    #[test]
    fn attention_matches_scalar_reference_bit_for_bit() {
        // the batched GEMM path must reproduce the retained scalar
        // attention EXACTLY — forward context, probabilities, and all
        // three backward cotangents — in both masking modes
        let dims = BlockDims { d_model: 12, n_layers: 1, n_heads: 3, d_ff: 24 };
        let (b, s) = (2usize, 5usize);
        let mut rng = Rng::new(7);
        let q = Matrix::gaussian(b * s, dims.d_model, 1.0, &mut rng);
        let k = Matrix::gaussian(b * s, dims.d_model, 1.0, &mut rng);
        let v = Matrix::gaussian(b * s, dims.d_model, 1.0, &mut rng);
        let dctx = Matrix::gaussian(b * s, dims.d_model, 1.0, &mut rng);
        for causal in [true, false] {
            let (ctx, probs) = attention_forward(&q, &k, &v, dims, b, s, causal);
            let (ctx_ref, probs_ref) =
                reference::attention_forward(&q, &k, &v, dims, b, s, causal);
            assert!(ctx.allclose(&ctx_ref, 0.0), "ctx (causal={causal})");
            for (p, want) in (0..probs.batch).zip(probs_ref.iter()) {
                assert_eq!(probs.panel(p), &want.data[..], "probs panel {p}");
            }
            let (dq, dk, dv) =
                attention_backward(&q, &k, &v, &probs, &dctx, dims, b, s);
            let (dq_ref, dk_ref, dv_ref) = reference::attention_backward(
                &q, &k, &v, &probs_ref, &dctx, dims, b, s,
            );
            assert!(dq.allclose(&dq_ref, 0.0), "dq (causal={causal})");
            assert!(dk.allclose(&dk_ref, 0.0), "dk (causal={causal})");
            assert!(dv.allclose(&dv_ref, 0.0), "dv (causal={causal})");
        }
    }

    #[test]
    fn fused_attention_backward_dispatch_matches_unfused_oracle() {
        // the single-submission backward dispatch vs the retained
        // four-call sequence: raw bits, NaN/Inf included (kernel-oracle
        // convention — a fast path may not launder non-finite values)
        let dims = BlockDims { d_model: 12, n_layers: 1, n_heads: 3, d_ff: 24 };
        let (b, s) = (2usize, 5usize);
        let (h, dh) = (dims.n_heads, dims.head_dim());
        let mut rng = Rng::new(77);
        let q = Matrix::gaussian(b * s, dims.d_model, 1.0, &mut rng);
        let k = Matrix::gaussian(b * s, dims.d_model, 1.0, &mut rng);
        let v = Matrix::gaussian(b * s, dims.d_model, 1.0, &mut rng);
        let (_, probs) = attention_forward(&q, &k, &v, dims, b, s, true);
        let mut dctx = Matrix::gaussian(b * s, dims.d_model, 1.0, &mut rng);
        *dctx.at_mut(0, 0) = f32::NAN;
        *dctx.at_mut(1, 1) = f32::INFINITY;
        let qh = gather_heads(&q, b, s, h, dh);
        let kh = gather_heads(&k, b, s, h, dh);
        let vh = gather_heads(&v, b, s, h, dh);
        let dctxh = gather_heads(&dctx, b, s, h, dh);
        let scale = 1.0 / (dh as f32).sqrt();
        let (fq, fk, fv) =
            attention_backward_fused(&dctxh, &probs, &qh, &kh, &vh, scale);
        let (uq, uk, uv) =
            attention_backward_panels_unfused(&dctxh, &probs, &qh, &kh, &vh, scale);
        for (name, got, want) in [("dq", &fq, &uq), ("dk", &fk, &uk), ("dv", &fv, &uv)]
        {
            assert!(got.data.iter().any(|x| !x.is_finite()), "{name} poison lost");
            for (g, w) in got.data.iter().zip(want.data.iter()) {
                assert_eq!(g.to_bits(), w.to_bits(), "{name}");
            }
        }
    }

    #[test]
    fn fused_qkv_matches_unfused_reference() {
        // the fused [d,3d] projection against the retained naive unfused
        // oracle: forward thirds and the three parameter gradients must
        // be BIT-identical (independent GEMM column blocks); dn1 differs
        // only by one documented re-association, checked two ways
        let dims = BlockDims { d_model: 12, n_layers: 1, n_heads: 3, d_ff: 24 };
        let d = dims.d_model;
        let (b, s) = (2usize, 5usize);
        let (h, dh) = (dims.n_heads, dims.head_dim());
        let mut rng = Rng::new(31);
        let n1 = Matrix::gaussian(b * s, d, 1.0, &mut rng);
        let wq = Matrix::gaussian(d, d, 1.0, &mut rng);
        let wk = Matrix::gaussian(d, d, 1.0, &mut rng);
        let wv = Matrix::gaussian(d, d, 1.0, &mut rng);

        // forward: one fused GEMM, thirds bit-equal to the naive oracle
        let wqkv = Matrix::concat_cols(&[&wq, &wk, &wv]);
        let qkv = n1.matmul(&wqkv);
        let (q_ref, k_ref, v_ref) =
            reference::qkv_unfused::forward(&n1, &wq, &wk, &wv);
        let thirds = qkv.split_cols(&[d, d, d]);
        assert!(thirds[0].allclose(&q_ref, 0.0), "fused q");
        assert!(thirds[1].allclose(&k_ref, 0.0), "fused k");
        assert!(thirds[2].allclose(&v_ref, 0.0), "fused v");
        // the head panels sliced straight from the fused activation
        // match packing the separate projections
        for (col0, want) in [(0, &q_ref), (d, &k_ref), (2 * d, &v_ref)] {
            let direct = gather_heads_at(&qkv, b, s, h, dh, col0);
            let via = crate::tensor::gather_heads(want, b, s, h, dh);
            assert_eq!(direct.data, via.data, "panel at col {col0}");
        }

        // backward: fused dWqkv splits into bit-equal parameter grads
        let dq = Matrix::gaussian(b * s, d, 1.0, &mut rng);
        let dk = Matrix::gaussian(b * s, d, 1.0, &mut rng);
        let dv = Matrix::gaussian(b * s, d, 1.0, &mut rng);
        let dqkv = Matrix::concat_cols(&[&dq, &dk, &dv]);
        let dwqkv = n1.matmul_tn(&dqkv);
        let dn1 = dqkv.matmul_nt(&wqkv);
        let (dwq_ref, dwk_ref, dwv_ref, dn1_ref) =
            reference::qkv_unfused::backward(&n1, &wq, &wk, &wv, &dq, &dk, &dv);
        let dws = dwqkv.split_cols(&[d, d, d]);
        assert!(dws[0].allclose(&dwq_ref, 0.0), "dwq");
        assert!(dws[1].allclose(&dwk_ref, 0.0), "dwk");
        assert!(dws[2].allclose(&dwv_ref, 0.0), "dwv");
        // dn1: bit-equal to the naive kernel at the SAME (fused)
        // association, and within rounding of the pre-fusion association
        assert!(dn1.allclose(&dqkv.matmul_nt_naive(&wqkv), 0.0), "dn1 kernel");
        assert!(dn1.allclose(&dn1_ref, 1e-4), "dn1 association drift");
    }

    #[test]
    fn causal_mask_blocks_future_positions() {
        // token t's output must not depend on tokens after t
        let dims = dims();
        let params = toy_params(dims, 0);
        let (b, s) = (1usize, 4usize);
        let mut rng = Rng::new(1);
        let x0 = Matrix::gaussian(b * s, dims.d_model, 1.0, &mut rng);
        let (y, _) = stack_forward(&params, dims, x0.clone(), b, s, true);
        let mut x2 = x0.clone();
        for j in 0..dims.d_model {
            *x2.at_mut(s - 1, j) += 1.0; // perturb the LAST position only
        }
        let (y2, _) = stack_forward(&params, dims, x2, b, s, true);
        for i in 0..s - 1 {
            for j in 0..dims.d_model {
                assert_eq!(y.at(i, j), y2.at(i, j), "position {i} leaked");
            }
        }
        // ...while bidirectional attention propagates it everywhere
        let mut x3 = x0.clone();
        for j in 0..dims.d_model {
            *x3.at_mut(s - 1, j) += 1.0;
        }
        let (yb, _) = stack_forward(&params, dims, x0, b, s, false);
        let (yb2, _) = stack_forward(&params, dims, x3, b, s, false);
        assert!(!yb.allclose(&yb2, 1e-6));
    }

    #[test]
    fn stack_backward_matches_directional_finite_difference() {
        // f(params, x0) = <stack(x0), c>; check d/deps f(theta + eps*u)
        // against <grads, u> for a random direction u over ALL parameters
        // and the input.
        let dims = dims();
        let params = toy_params(dims, 2);
        let (b, s) = (2usize, 3usize);
        let mut rng = Rng::new(3);
        let x0 = Matrix::gaussian(b * s, dims.d_model, 1.0, &mut rng);
        let c = Matrix::gaussian(b * s, dims.d_model, 1.0, &mut rng);
        let f = |params: &ParamSet, x0: &Matrix| -> f32 {
            let (y, _) = stack_forward(params, dims, x0.clone(), b, s, true);
            y.data.iter().zip(c.data.iter()).map(|(a, b)| a * b).sum()
        };
        let (_, caches) = stack_forward(&params, dims, x0.clone(), b, s, true);
        let mut grads = ParamSet::new();
        let dx0 =
            stack_backward(&params, dims, caches, c.clone(), b, s, true, &mut grads);

        // random direction over every parameter + the input
        let mut dir_rng = Rng::new(4);
        let u: ParamSet = params
            .iter()
            .map(|(k, m)| {
                (k.clone(), Matrix::gaussian(m.rows, m.cols, 1.0, &mut dir_rng))
            })
            .collect();
        let ux = Matrix::gaussian(x0.rows, x0.cols, 1.0, &mut dir_rng);
        let eps = 1e-3f32;
        let shift = |sign: f32| -> (ParamSet, Matrix) {
            let p2: ParamSet = params
                .iter()
                .map(|(k, m)| {
                    let mut m2 = m.clone();
                    m2.add_scaled_inplace(&u[k], sign * eps);
                    (k.clone(), m2)
                })
                .collect();
            let mut x2 = x0.clone();
            x2.add_scaled_inplace(&ux, sign * eps);
            (p2, x2)
        };
        let (pp, xp) = shift(1.0);
        let (pm, xm) = shift(-1.0);
        let fd = (f(&pp, &xp) - f(&pm, &xm)) / (2.0 * eps);
        let mut analytic: f32 = dx0
            .data
            .iter()
            .zip(ux.data.iter())
            .map(|(a, b)| a * b)
            .sum();
        for (k, g) in &grads {
            analytic += g
                .data
                .iter()
                .zip(u[k].data.iter())
                .map(|(a, b)| a * b)
                .sum::<f32>();
        }
        assert!(
            (fd - analytic).abs() < 2e-2 * (1.0 + fd.abs().max(analytic.abs())),
            "fd={fd} analytic={analytic}"
        );
    }
}
