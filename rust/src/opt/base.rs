//! The `BaseOptimizer` trait and its three backend-free implementations.
//!
//! These mirror `python/compile/optimizers.py` (the L2 half of the
//! contract) so the native backend's fused steps compute the same updates
//! the AOT graphs do:
//!
//!   * `Sgd`       — plain SGD, stateless.
//!   * `Adam`      — Kingma & Ba 2015 with bias correction; state is the
//!     full-size `m`/`v` pair (the paper's motivating example of
//!     linear-memory optimizer state).
//!   * `Adafactor` — Shazeer & Stern 2018 with an external learning rate
//!     (`relative_step=False`), factored row/col second moments, update
//!     clipping d=1.0 and a parameter-scale-relative step. The paper's
//!     Table-1/2 base optimizer. `Adafactor::unfactored()` is the Table-4
//!     "linear-memory optimizer" ablation keeping a full second moment.
//!
//! All state tensors are 2-D `tensor::Matrix` values so they serialize
//! straight into the manifest ABI's f32 state groups (row moments are
//! `[n, 1]`, column moments `[1, m]`).

use crate::tensor::Matrix;

/// A base optimizer over 2-D parameters: owns the per-parameter state
/// layout and the update rule. Implementations must be deterministic pure
/// functions of `(param, grad, state, lr, step)` — the fused executables
/// re-run them bit-identically on checkpoint resume.
pub trait BaseOptimizer {
    /// ABI name ("sgd" / "adam" / "adafactor" / "adafactor_nofactor").
    fn name(&self) -> &'static str;

    /// `(slot suffix, [rows, cols])` of each state tensor kept for one
    /// `[n, m]` parameter, in update order. Slot suffixes match the L2
    /// state dict keys (`{param}/m`, `{param}/vr`, ...).
    fn state_shapes(&self, n: usize, m: usize) -> Vec<(&'static str, [usize; 2])>;

    /// Zero-initialized state for one `[n, m]` parameter.
    fn init_state(&self, n: usize, m: usize) -> Vec<Matrix> {
        self.state_shapes(n, m)
            .iter()
            .map(|(_, s)| Matrix::zeros(s[0], s[1]))
            .collect()
    }

    /// Apply one update in place. `step` is the number of updates already
    /// taken (bias corrections use t = step + 1). `state` must have the
    /// layout produced by [`BaseOptimizer::init_state`].
    fn update(
        &self,
        param: &mut Matrix,
        grad: &Matrix,
        state: &mut [Matrix],
        lr: f32,
        step: f32,
    ) -> Result<(), String>;
}

/// Boxed optimizers compose like concrete ones (the native catalog builds
/// them from [`crate::opt::OptimizerKind`] at execution time).
impl BaseOptimizer for Box<dyn BaseOptimizer> {
    fn name(&self) -> &'static str {
        (**self).name()
    }

    fn state_shapes(&self, n: usize, m: usize) -> Vec<(&'static str, [usize; 2])> {
        (**self).state_shapes(n, m)
    }

    fn init_state(&self, n: usize, m: usize) -> Vec<Matrix> {
        (**self).init_state(n, m)
    }

    fn update(
        &self,
        param: &mut Matrix,
        grad: &Matrix,
        state: &mut [Matrix],
        lr: f32,
        step: f32,
    ) -> Result<(), String> {
        (**self).update(param, grad, state, lr, step)
    }
}

fn check_state(
    who: &str,
    param: &Matrix,
    grad: &Matrix,
    state: &[Matrix],
    want: usize,
) -> Result<(), String> {
    if param.shape() != grad.shape() {
        return Err(format!(
            "{who}: param {:?} vs grad {:?} shape mismatch",
            param.shape(),
            grad.shape()
        ));
    }
    if state.len() != want {
        return Err(format!(
            "{who}: expected {want} state tensors, got {}",
            state.len()
        ));
    }
    Ok(())
}

fn rms(data: &[f32]) -> f32 {
    if data.is_empty() {
        return 0.0;
    }
    let ss: f64 = data.iter().map(|&x| (x as f64) * (x as f64)).sum();
    (ss / data.len() as f64).sqrt() as f32
}

// ---------------------------------------------------------------------
// SGD
// ---------------------------------------------------------------------

/// Plain SGD: `w -= lr * g`. Stateless.
#[derive(Clone, Copy, Debug, Default)]
pub struct Sgd;

impl BaseOptimizer for Sgd {
    fn name(&self) -> &'static str {
        "sgd"
    }

    fn state_shapes(&self, _n: usize, _m: usize) -> Vec<(&'static str, [usize; 2])> {
        Vec::new()
    }

    fn update(
        &self,
        param: &mut Matrix,
        grad: &Matrix,
        state: &mut [Matrix],
        lr: f32,
        _step: f32,
    ) -> Result<(), String> {
        check_state("sgd", param, grad, state, 0)?;
        param.add_scaled_inplace(grad, -lr);
        Ok(())
    }
}

// ---------------------------------------------------------------------
// Adam
// ---------------------------------------------------------------------

/// Adam with bias correction. State: full-size `m` and `v`.
#[derive(Clone, Copy, Debug)]
pub struct Adam {
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
}

impl Default for Adam {
    fn default() -> Self {
        Self { beta1: 0.9, beta2: 0.999, eps: 1e-8 }
    }
}

impl Adam {
    pub fn new() -> Self {
        Self::default()
    }

    /// One Adam moment update + bias-corrected direction, shared between
    /// [`BaseOptimizer::update`] and the GaLore Adam-in-subspace step
    /// (which applies the same rule to COMPRESSED moments before
    /// decompressing the direction).
    pub fn direction(&self, m: &mut Matrix, v: &mut Matrix, g: &Matrix, step: f32) -> Matrix {
        assert_eq!(m.shape(), g.shape(), "adam m/grad shape mismatch");
        assert_eq!(v.shape(), g.shape(), "adam v/grad shape mismatch");
        let t = step + 1.0;
        let bc1 = 1.0 - self.beta1.powf(t);
        let bc2 = 1.0 - self.beta2.powf(t);
        let mut dir = Matrix::zeros(g.rows, g.cols);
        for i in 0..g.data.len() {
            let gi = g.data[i];
            let mi = self.beta1 * m.data[i] + (1.0 - self.beta1) * gi;
            let vi = self.beta2 * v.data[i] + (1.0 - self.beta2) * gi * gi;
            m.data[i] = mi;
            v.data[i] = vi;
            dir.data[i] = (mi / bc1) / ((vi / bc2).max(0.0).sqrt() + self.eps);
        }
        dir
    }
}

impl BaseOptimizer for Adam {
    fn name(&self) -> &'static str {
        "adam"
    }

    fn state_shapes(&self, n: usize, m: usize) -> Vec<(&'static str, [usize; 2])> {
        vec![("m", [n, m]), ("v", [n, m])]
    }

    fn update(
        &self,
        param: &mut Matrix,
        grad: &Matrix,
        state: &mut [Matrix],
        lr: f32,
        step: f32,
    ) -> Result<(), String> {
        check_state("adam", param, grad, state, 2)?;
        let (ms, vs) = state.split_at_mut(1);
        if ms[0].shape() != param.shape() || vs[0].shape() != param.shape() {
            return Err(format!(
                "adam: state shapes {:?}/{:?} do not match param {:?}",
                ms[0].shape(),
                vs[0].shape(),
                param.shape()
            ));
        }
        let dir = self.direction(&mut ms[0], &mut vs[0], grad, step);
        param.add_scaled_inplace(&dir, -lr);
        Ok(())
    }
}

// ---------------------------------------------------------------------
// Adafactor
// ---------------------------------------------------------------------

/// Adafactor with external learning rate, mirroring the L2 implementation:
/// t-scheduled decay β₂(t) = 1 − t^(−0.8), factored row/col second moments
/// (or a full second moment when `factored` is off), update clipping
/// `u /= max(1, RMS(u)/d)` with d = 1, and a parameter-scale-relative step
/// `w -= lr · max(eps2, RMS(w)) · u`.
#[derive(Clone, Copy, Debug)]
pub struct Adafactor {
    pub factored: bool,
    pub eps1: f32,
    pub eps2: f32,
    pub clip_threshold: f32,
    pub decay_exponent: f32,
}

impl Default for Adafactor {
    fn default() -> Self {
        Self {
            factored: true,
            eps1: 1e-30,
            eps2: 1e-3,
            clip_threshold: 1.0,
            decay_exponent: 0.8,
        }
    }
}

impl Adafactor {
    pub fn new() -> Self {
        Self::default()
    }

    /// The Table-4 "linear-memory optimizer" ablation: full second moment.
    pub fn unfactored() -> Self {
        Self { factored: false, ..Self::default() }
    }

    fn beta2(&self, step: f32) -> f32 {
        let t = step + 1.0;
        1.0 - t.powf(-self.decay_exponent)
    }

    /// The EMA'd second-moment estimate `v̂` the update divides by —
    /// reconstructed from the factored state (`v̂ = vr vcᵀ / mean(vr)`)
    /// or read directly from the full state. Exposed for diagnostics and
    /// the factored-vs-full property tests.
    pub fn second_moment(&self, state: &[Matrix]) -> Result<Matrix, String> {
        if self.factored {
            if state.len() != 2 {
                return Err(format!(
                    "adafactor: expected [vr, vc] state, got {} tensors",
                    state.len()
                ));
            }
            let (vr, vc) = (&state[0], &state[1]);
            let n = vr.rows;
            let m = vc.cols;
            let mean_vr =
                (vr.data.iter().map(|&x| x as f64).sum::<f64>() / n.max(1) as f64) as f32;
            let denom = mean_vr.max(self.eps1);
            Ok(Matrix::from_fn(n, m, |i, j| vr.at(i, 0) * vc.at(0, j) / denom))
        } else {
            if state.len() != 1 {
                return Err(format!(
                    "adafactor_nofactor: expected [v] state, got {} tensors",
                    state.len()
                ));
            }
            Ok(state[0].clone())
        }
    }
}

impl BaseOptimizer for Adafactor {
    fn name(&self) -> &'static str {
        if self.factored {
            "adafactor"
        } else {
            "adafactor_nofactor"
        }
    }

    fn state_shapes(&self, n: usize, m: usize) -> Vec<(&'static str, [usize; 2])> {
        if self.factored {
            vec![("vr", [n, 1]), ("vc", [1, m])]
        } else {
            vec![("v", [n, m])]
        }
    }

    fn update(
        &self,
        param: &mut Matrix,
        grad: &Matrix,
        state: &mut [Matrix],
        lr: f32,
        step: f32,
    ) -> Result<(), String> {
        let (n, m) = grad.shape();
        let b2 = self.beta2(step);
        let mut u = Matrix::zeros(n, m);
        if self.factored {
            check_state("adafactor", param, grad, state, 2)?;
            let (vrs, vcs) = state.split_at_mut(1);
            let vr = &mut vrs[0];
            let vc = &mut vcs[0];
            if vr.shape() != (n, 1) || vc.shape() != (1, m) {
                return Err(format!(
                    "adafactor: state shapes {:?}/{:?} do not match param {:?}",
                    vr.shape(),
                    vc.shape(),
                    param.shape()
                ));
            }
            // EMA the row/col means of g^2 + eps1 (mirrors jnp.mean axes)
            for i in 0..n {
                let row = grad.row(i);
                let mean: f32 = row.iter().map(|&g| g * g + self.eps1).sum::<f32>() / m as f32;
                let x = vr.at_mut(i, 0);
                *x = b2 * *x + (1.0 - b2) * mean;
            }
            for j in 0..m {
                let mut sum = 0.0f32;
                for i in 0..n {
                    let g = grad.at(i, j);
                    sum += g * g + self.eps1;
                }
                let x = vc.at_mut(0, j);
                *x = b2 * *x + (1.0 - b2) * sum / n as f32;
            }
            // u = g / (sqrt(vr/mean(vr)) ⊗ sqrt(vc))
            let mean_vr =
                (vr.data.iter().map(|&x| x as f64).sum::<f64>() / n as f64) as f32;
            let denom = mean_vr.max(self.eps1);
            for i in 0..n {
                let ri = (vr.at(i, 0) / denom).sqrt();
                for j in 0..m {
                    *u.at_mut(i, j) = grad.at(i, j) / (ri * vc.at(0, j).sqrt());
                }
            }
        } else {
            check_state("adafactor_nofactor", param, grad, state, 1)?;
            let v = &mut state[0];
            if v.shape() != (n, m) {
                return Err(format!(
                    "adafactor_nofactor: state shape {:?} does not match param {:?}",
                    v.shape(),
                    param.shape()
                ));
            }
            for i in 0..v.data.len() {
                let g = grad.data[i];
                v.data[i] = b2 * v.data[i] + (1.0 - b2) * (g * g + self.eps1);
                u.data[i] = g / v.data[i].sqrt();
            }
        }
        // update clipping: u /= max(1, RMS(u)/d)
        let clip = (rms(&u.data) / self.clip_threshold).max(1.0);
        // parameter-scale-relative step with the eps2 floor
        let scale = rms(&param.data).max(self.eps2);
        param.add_scaled_inplace(&u, -lr * scale / clip);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randn(seed: u64, n: usize, m: usize) -> Matrix {
        let mut rng = Rng::new(seed);
        Matrix::gaussian(n, m, 1.0, &mut rng)
    }

    #[test]
    fn sgd_matches_manual_step() {
        let mut w = randn(0, 4, 5);
        let want = {
            let mut w2 = w.clone();
            let g = randn(1, 4, 5);
            w2.add_scaled_inplace(&g, -0.1);
            w2
        };
        let g = randn(1, 4, 5);
        let mut state = Sgd.init_state(4, 5);
        Sgd.update(&mut w, &g, &mut state, 0.1, 0.0).unwrap();
        assert!(w.allclose(&want, 0.0));
        assert!(state.is_empty());
    }

    #[test]
    fn adam_state_layout_and_descent() {
        let adam = Adam::new();
        assert_eq!(
            adam.state_shapes(3, 7),
            vec![("m", [3usize, 7usize]), ("v", [3, 7])]
        );
        let mut w = Matrix::zeros(3, 7);
        let g = randn(2, 3, 7);
        let mut st = adam.init_state(3, 7);
        adam.update(&mut w, &g, &mut st, 0.01, 0.0).unwrap();
        // every coordinate moved against the gradient sign
        for (x, gg) in w.data.iter().zip(g.data.iter()) {
            assert!(x * gg <= 0.0, "moved with the gradient: {x} vs {gg}");
        }
    }

    #[test]
    fn adam_rejects_wrong_state_arity() {
        let adam = Adam::new();
        let mut w = Matrix::zeros(2, 2);
        let g = Matrix::zeros(2, 2);
        let mut st = vec![Matrix::zeros(2, 2)];
        assert!(adam.update(&mut w, &g, &mut st, 0.1, 0.0).is_err());
    }

    #[test]
    fn adafactor_state_is_sublinear() {
        let af = Adafactor::new();
        let shapes = af.state_shapes(100, 200);
        assert_eq!(shapes, vec![("vr", [100usize, 1usize]), ("vc", [1, 200])]);
        let full = Adafactor::unfactored();
        assert_eq!(full.state_shapes(100, 200), vec![("v", [100usize, 200usize])]);
        assert_eq!(full.name(), "adafactor_nofactor");
    }

    #[test]
    fn adafactor_update_clipped_and_scaled() {
        // a huge gradient must not blow past lr * RMS(w) * clip_threshold
        let af = Adafactor::new();
        let mut w = randn(3, 8, 8);
        let before = w.clone();
        let g = randn(4, 8, 8).scale(1e4);
        let mut st = af.init_state(8, 8);
        af.update(&mut w, &g, &mut st, 0.1, 0.0).unwrap();
        let delta = (&w - &before).frobenius_norm();
        let bound = 0.1 * rms(&before.data) * (8.0 * 8.0f32).sqrt() * 1.5;
        assert!(delta <= bound, "delta {delta} vs bound {bound}");
    }

    #[test]
    fn boxed_optimizer_forwards() {
        let boxed: Box<dyn BaseOptimizer> = Box::new(Adam::new());
        assert_eq!(boxed.name(), "adam");
        assert_eq!(boxed.state_shapes(2, 3).len(), 2);
        let mut w = Matrix::zeros(2, 3);
        let g = randn(5, 2, 3);
        let mut st = boxed.init_state(2, 3);
        boxed.update(&mut w, &g, &mut st, 0.01, 0.0).unwrap();
        assert!(w.frobenius_norm() > 0.0);
    }
}
