//! `FloraCompressor` — the paper's Algorithms 1 and 2 as a reusable
//! composition of seeded random projections (`rp`) with any
//! [`BaseOptimizer`].
//!
//! The compressor owns the projection-side state conventions: the
//! per-parameter seed derivation (Algorithm 1 line 3: every weight matrix
//! gets an *independent* projection from one cycle seed), the compressed
//! accumulator `C = Σ G Aᵀ`, the momentum EMA kept **in the subspace**,
//! and the κ-resample subspace transfer `M ← M A_old A_newᵀ`. The base
//! optimizer only ever sees full-size (decompressed) gradients, so any
//! `BaseOptimizer` composes without knowing FLORA exists — mirroring how
//! `python/compile/flora.py` hands `optimizer.update` the decompressed
//! effective gradient.

use super::base::BaseOptimizer;
use crate::rp;
use crate::tensor::Matrix;

/// Default EMA decay of the Algorithm-2 momentum.
pub const MOMENTUM_BETA: f32 = 0.9;

/// What the κ-interval seed schedule tells one momentum step (the
/// coordinator's `MomentumSeeds::tick` maps 1:1 onto this).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SubspaceTick {
    /// Seed of the subspace the momentum currently lives in.
    pub seed_cur: u64,
    /// Seed of the next subspace (only read on resample steps).
    pub seed_next: u64,
    /// True exactly on κ-interval boundaries.
    pub resample: bool,
    /// Whether resampling moves the EMA via the subspace transfer
    /// (false = the paper's §2.4 remedy-#2 ablation: the old coordinates
    /// are silently reinterpreted in the new subspace).
    pub transfer: bool,
}

impl SubspaceTick {
    /// The projection seed ACTIVE for this tick's gradient compression:
    /// `seed_next` on resample steps (the freshly sampled subspace),
    /// `seed_cur` otherwise. Data-parallel workers compress with this
    /// seed so the reduced compressed gradient lands in the same
    /// subspace the momentum EMA lives in after any transfer.
    pub fn active_seed(&self) -> u64 {
        if self.resample {
            self.seed_next
        } else {
            self.seed_cur
        }
    }
}

/// Algorithm-1/-2 state machine over one parameter matrix, composing a
/// [`BaseOptimizer`] with the `rp` projection algebra.
///
/// # Example: one accumulate→apply cycle (Algorithm 1)
///
/// ```
/// use flora::opt::{BaseOptimizer, FloraCompressor, Sgd};
/// use flora::tensor::Matrix;
///
/// let comp = FloraCompressor::new(Sgd, 4);
/// let mut w = Matrix::zeros(8, 16);
/// let mut acc = Matrix::zeros(8, 4); // compressed accumulator [n, r]
/// let mut opt_state = comp.base().init_state(8, 16);
/// let g = Matrix::from_fn(8, 16, |i, j| ((i + j) % 3) as f32 * 0.1);
///
/// let seed = comp.param_seed(7, 0); // cycle seed 7, parameter index 0
/// comp.accumulate(&mut acc, &g, seed); // micro step: C += G Aᵀ
/// comp.accumulate(&mut acc, &g, seed); // same cycle seed for every micro
/// // cycle end: decompress the mean of τ=2 micros, base-optimizer step
/// comp.apply_accumulated(&mut w, &acc, &mut opt_state, seed, 2.0, 0.1, 0.0)
///     .unwrap();
/// assert!(w.frobenius_norm() > 0.0);
/// ```
#[derive(Clone, Debug)]
pub struct FloraCompressor<O> {
    base: O,
    rank: usize,
    beta: f32,
}

impl<O: BaseOptimizer> FloraCompressor<O> {
    pub fn new(base: O, rank: usize) -> Self {
        Self { base, rank, beta: MOMENTUM_BETA }
    }

    /// Override the momentum EMA decay (Algorithm 2's β).
    pub fn with_beta(mut self, beta: f32) -> Self {
        self.beta = beta;
        self
    }

    pub fn base(&self) -> &O {
        &self.base
    }

    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn beta(&self) -> f32 {
        self.beta
    }

    /// The independent per-parameter seed for a cycle: parameter `index`
    /// under the coordinator-issued `cycle_seed` (Algorithm 1 line 3).
    pub fn param_seed(&self, cycle_seed: u64, index: usize) -> u64 {
        rp::param_seed(cycle_seed, index)
    }

    /// Regenerate this parameter's projection A ∈ R^{r×m} from its seed.
    pub fn projection(&self, seed: u64, m: usize) -> Matrix {
        rp::projection(seed, self.rank, m)
    }

    /// Algorithm 1 line 9 (micro step): `acc += G Aᵀ`, with A regenerated
    /// from the cycle seed shared by all τ micro-steps.
    pub fn accumulate(&self, acc: &mut Matrix, grad: &Matrix, seed: u64) {
        let a = self.projection(seed, grad.cols);
        rp::compress_accumulate(acc, grad, &a);
    }

    /// Algorithm 1 cycle end: decompress the mean gradient with the SAME
    /// seed the micros used and hand it to the base optimizer. The caller
    /// zeroes the accumulator and resamples afterwards.
    #[allow(clippy::too_many_arguments)]
    pub fn apply_accumulated(
        &self,
        param: &mut Matrix,
        acc: &Matrix,
        opt_state: &mut [Matrix],
        seed: u64,
        tau: f32,
        lr: f32,
        step: f32,
    ) -> Result<(), String> {
        let a = self.projection(seed, param.cols);
        let ghat = rp::decompress(acc, &a).scale(1.0 / tau.max(1.0));
        self.base.update(param, &ghat, opt_state, lr, step)
    }

    /// One Algorithm-2 step: on resample (optionally) transfer the EMA
    /// into the next subspace, EMA the compressed gradient, then feed the
    /// decompressed momentum to the base optimizer as the effective
    /// gradient (momentum-in-subspace, second moments full-size).
    #[allow(clippy::too_many_arguments)]
    pub fn momentum_step(
        &self,
        param: &mut Matrix,
        mom: &mut Matrix,
        opt_state: &mut [Matrix],
        grad: &Matrix,
        tick: SubspaceTick,
        lr: f32,
        step: f32,
    ) -> Result<(), String> {
        // compress with the tick's ACTIVE projection (a_new on resample
        // steps); transfer only mutates `mom` and compression only reads
        // `grad`, so compressing up front is bit-identical to the
        // pre-refactor order that built A inside the resample branch
        let a = self.projection(tick.active_seed(), grad.cols);
        let c = rp::compress(grad, &a);
        self.momentum_step_compressed(param, mom, opt_state, &c, tick, lr, step)
    }

    /// [`momentum_step`](Self::momentum_step) on a **pre-compressed**
    /// gradient `c = G Aᵀ` (A = the active projection of this tick, see
    /// [`SubspaceTick::active_seed`]). This is the data-parallel entry
    /// point: dp workers compress their shard gradients locally, the
    /// reducer sums the compressed states in fixed shard order, and only
    /// the reduced (and mean-scaled) `c` reaches the step — exact by
    /// linearity of compression, `Σ_s G_s Aᵀ = (Σ_s G_s) Aᵀ`.
    #[allow(clippy::too_many_arguments)]
    pub fn momentum_step_compressed(
        &self,
        param: &mut Matrix,
        mom: &mut Matrix,
        opt_state: &mut [Matrix],
        c: &Matrix,
        tick: SubspaceTick,
        lr: f32,
        step: f32,
    ) -> Result<(), String> {
        let m_dim = param.cols;
        // Algorithm 2 line 13: seed_cur is the OLD subspace on resample
        // steps; the transfer moves the EMA before the new coordinates
        // are blended in (and A(seed_next) stays the active projection).
        let a = if tick.resample {
            let a_new = self.projection(tick.seed_next, m_dim);
            if tick.transfer {
                let a_old = self.projection(tick.seed_cur, m_dim);
                *mom = rp::transfer(mom, &a_old, &a_new);
            }
            a_new
        } else {
            self.projection(tick.seed_cur, m_dim)
        };
        let mut next = mom.scale(self.beta);
        next.add_scaled_inplace(c, 1.0 - self.beta);
        *mom = next;
        let eff = rp::decompress(mom, &a);
        self.base.update(param, &eff, opt_state, lr, step)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::opt::base::Sgd;
    use crate::util::rng::Rng;

    fn randn(seed: u64, n: usize, m: usize) -> Matrix {
        let mut rng = Rng::new(seed);
        Matrix::gaussian(n, m, 1.0, &mut rng)
    }

    #[test]
    fn accumulate_delegates_to_rp() {
        let comp = FloraCompressor::new(Sgd, 4);
        let g = randn(0, 8, 24);
        let mut acc = Matrix::zeros(8, 4);
        comp.accumulate(&mut acc, &g, 99);
        let a = rp::projection(99, 4, 24);
        assert!(acc.allclose(&rp::compress(&g, &a), 1e-6));
    }

    #[test]
    fn apply_accumulated_with_sgd_matches_manual_decompress() {
        let comp = FloraCompressor::new(Sgd, 4);
        let g = randn(1, 8, 24);
        let mut acc = Matrix::zeros(8, 4);
        for _ in 0..3 {
            comp.accumulate(&mut acc, &g, 7);
        }
        let mut w = randn(2, 8, 24);
        let mut want = w.clone();
        let mut st = Vec::new();
        comp.apply_accumulated(&mut w, &acc, &mut st, 7, 3.0, 0.5, 0.0)
            .unwrap();
        let a = rp::projection(7, 4, 24);
        let ghat = rp::decompress(&acc, &a).scale(1.0 / 3.0);
        want.add_scaled_inplace(&ghat, -0.5);
        assert!(w.allclose(&want, 1e-6));
    }

    #[test]
    fn momentum_transfer_only_on_resample() {
        let comp = FloraCompressor::new(Sgd, 4);
        let g = randn(3, 8, 24);
        let run = |resample: bool, transfer: bool| {
            let mut w = randn(4, 8, 24);
            let mut mom = randn(5, 8, 4).scale(0.1);
            let mut st = Vec::new();
            comp.momentum_step(
                &mut w,
                &mut mom,
                &mut st,
                &g,
                SubspaceTick { seed_cur: 10, seed_next: 11, resample, transfer },
                0.1,
                0.0,
            )
            .unwrap();
            mom
        };
        let quiet = run(false, true);
        let transferred = run(true, true);
        let reinterpreted = run(true, false);
        // the transfer rotates the EMA; the ablation keeps coordinates
        assert!(!quiet.allclose(&transferred, 1e-5));
        assert!(!transferred.allclose(&reinterpreted, 1e-5));
    }

    #[test]
    fn momentum_step_compressed_bit_matches_momentum_step() {
        let comp = FloraCompressor::new(Sgd, 4);
        let g = randn(6, 8, 24);
        for (resample, transfer) in [(false, true), (true, true), (true, false)] {
            let tick = SubspaceTick { seed_cur: 10, seed_next: 11, resample, transfer };
            let mut w1 = randn(7, 8, 24);
            let mut m1 = randn(8, 8, 4).scale(0.1);
            let mut s1 = Vec::new();
            comp.momentum_step(&mut w1, &mut m1, &mut s1, &g, tick, 0.1, 0.0).unwrap();

            // identical starting state (randn is seed-deterministic)
            let mut w2 = randn(7, 8, 24);
            let mut m2 = randn(8, 8, 4).scale(0.1);
            let mut s2 = Vec::new();
            let a = comp.projection(tick.active_seed(), g.cols);
            let c = rp::compress(&g, &a);
            comp.momentum_step_compressed(&mut w2, &mut m2, &mut s2, &c, tick, 0.1, 0.0)
                .unwrap();

            let b1: Vec<u32> = w1.data.iter().map(|x| x.to_bits()).collect();
            let b2: Vec<u32> = w2.data.iter().map(|x| x.to_bits()).collect();
            assert_eq!(b1, b2, "resample={resample} transfer={transfer}");
            let mb1: Vec<u32> = m1.data.iter().map(|x| x.to_bits()).collect();
            let mb2: Vec<u32> = m2.data.iter().map(|x| x.to_bits()).collect();
            assert_eq!(mb1, mb2, "momentum resample={resample} transfer={transfer}");
        }
    }

    #[test]
    fn compression_is_linear_over_shard_gradients() {
        // the dp reducer's theorem: Σ_s compress(G_s) == compress(Σ_s G_s)
        let comp = FloraCompressor::new(Sgd, 4);
        let shards: Vec<Matrix> = (0..3).map(|s| randn(20 + s, 8, 24)).collect();
        let a = comp.projection(77, 24);
        let summed = Matrix::reduce_sum(&shards.iter().collect::<Vec<_>>());
        let of_sum = rp::compress(&summed, &a);
        let mut sum_of = Matrix::zeros(8, 4);
        for g in &shards {
            sum_of.add_scaled_inplace(&rp::compress(g, &a), 1.0);
        }
        assert!(sum_of.allclose(&of_sum, 1e-4));
    }

    #[test]
    fn param_seeds_are_independent_per_index() {
        let comp = FloraCompressor::new(Sgd, 4);
        let s0 = comp.param_seed(42, 0);
        let s1 = comp.param_seed(42, 1);
        assert_ne!(s0, s1);
        assert_eq!(s0, comp.param_seed(42, 0));
    }
}
