//! `AltLoraCompressor` — alternating-projection gradient compression.
//!
//! AltLoRA's claim (PAPERS.md) is that *solving* for the best rank-r
//! factors of the accumulated gradient beats reading it back through the
//! fixed random projection it was compressed with. The catch for a
//! sublinear-state compressor: the full gradient is never materialized
//! between micro-steps, so the solve must run on sketches. This module
//! keeps TWO seeded sketches per parameter `G ∈ R^{n×m}` (both linear in
//! `G`, so Algorithm-1 accumulation works unchanged):
//!
//! * the right sketch `C = Σ G Aᵀ ∈ R^{n×r}` — Flora's own accumulator,
//!   with `A ∈ R^{r×m}` regenerated from the cycle seed, and
//! * the left sketch `R = Σ P G ∈ R^{r×m}` — a probe `P ∈ R^{r×n}`
//!   regenerated from a seed *derived* from the same cycle seed, so the
//!   `rp` seed lifecycle (per-parameter derivation, cycle advance)
//!   carries over untouched.
//!
//! At cycle end one alternating-projection pass reconstructs the best
//! rank-r estimate from the two sketches (mean gradients `c̄ = C/τ`,
//! `r̄ = R/τ`):
//!
//! 1. **A-step** — sketched least squares for the right factor with the
//!    left factor pinned at `Pᵀ`: `A₁ = (P Pᵀ + εI)⁻¹ r̄` (an SPD r×r
//!    solve).
//! 2. **B-step** — exact right-sketch consistency `B₁ (A₁ Aᵀ) = c̄`
//!    (a general r×r solve with partial pivoting), so the estimate
//!    `Ĝ = B₁ A₁` reproduces the observed accumulator: `Ĝ Aᵀ = c̄`.
//!
//! When the mean gradient has rank <= r the reconstruction is *exact*
//! for generic sketches — strictly better than Flora's `c̄ A`, which
//! only approaches `Ḡ` in expectation over seeds. The base optimizer
//! sees the full-size estimate, exactly like [`super::FloraCompressor`].

use super::base::BaseOptimizer;
use crate::rp;
use crate::tensor::Matrix;
use crate::util::rng::derive_seed;

/// Tag deriving the left-probe seed from a cycle's right-projection seed.
const LEFT_PROBE_TAG: u64 = 0xA17_10_2A;

/// Relative ridge added to both r×r solves (scaled by the mean diagonal
/// magnitude, so conditioning is dimensionless).
const RIDGE_EPS: f32 = 1e-4;

/// Alternating-projection compressor over one parameter matrix: dual
/// seeded sketches in, best rank-r gradient estimate out, any
/// [`BaseOptimizer`] underneath.
///
/// # Example: one accumulate→apply cycle
///
/// ```
/// use flora::opt::{AltLoraCompressor, BaseOptimizer, Sgd};
/// use flora::tensor::Matrix;
///
/// let comp = AltLoraCompressor::new(Sgd, 4);
/// let mut w = Matrix::zeros(8, 16);
/// let mut acc = Matrix::zeros(8, 4); // right sketch [n, r]
/// let mut ralt = Matrix::zeros(4, 16); // left sketch [r, m]
/// let mut opt_state = comp.base().init_state(8, 16);
/// let g = Matrix::from_fn(8, 16, |i, j| ((i + 2 * j) % 5) as f32 * 0.1);
///
/// let seed = comp.param_seed(7, 0);
/// comp.accumulate(&mut acc, &mut ralt, &g, seed); // both sketches, one seed
/// comp.accumulate(&mut acc, &mut ralt, &g, seed);
/// comp.apply_accumulated(&mut w, &acc, &ralt, &mut opt_state, seed, 2.0, 0.1, 0.0)
///     .unwrap();
/// assert!(w.frobenius_norm() > 0.0);
/// ```
#[derive(Clone, Debug)]
pub struct AltLoraCompressor<O> {
    base: O,
    rank: usize,
}

impl<O: BaseOptimizer> AltLoraCompressor<O> {
    pub fn new(base: O, rank: usize) -> Self {
        Self { base, rank }
    }

    pub fn base(&self) -> &O {
        &self.base
    }

    pub fn rank(&self) -> usize {
        self.rank
    }

    /// Per-parameter cycle seed — same derivation as Flora Algorithm 1.
    pub fn param_seed(&self, cycle_seed: u64, index: usize) -> u64 {
        rp::param_seed(cycle_seed, index)
    }

    /// Right projection A ∈ R^{r×m} from the cycle seed (Flora's law).
    pub fn right_projection(&self, seed: u64, m: usize) -> Matrix {
        rp::projection(seed, self.rank, m)
    }

    /// Left probe P ∈ R^{r×n} from the derived probe seed.
    pub fn left_probe(&self, seed: u64, n: usize) -> Matrix {
        rp::projection(derive_seed(seed, LEFT_PROBE_TAG), self.rank, n)
    }

    /// Micro step: `acc += G Aᵀ` and `ralt += P G`, both regenerated from
    /// the one cycle seed shared by all τ micros. Linearity of both
    /// sketches is what makes shared-seed accumulation exact.
    pub fn accumulate(&self, acc: &mut Matrix, ralt: &mut Matrix, grad: &Matrix, seed: u64) {
        let a = self.right_projection(seed, grad.cols);
        rp::compress_accumulate(acc, grad, &a);
        let p = self.left_probe(seed, grad.rows);
        let left = p.matmul(grad);
        ralt.add_scaled_inplace(&left, 1.0);
    }

    /// The alternating-projection estimate Ĝ ∈ R^{n×m} from the two mean
    /// sketches (`tau` divides both accumulators).
    pub fn estimate(
        &self,
        acc: &Matrix,
        ralt: &Matrix,
        seed: u64,
        tau: f32,
    ) -> Result<Matrix, String> {
        let n = acc.rows;
        let m = ralt.cols;
        let c_mean = acc.scale(1.0 / tau.max(1.0));
        let r_mean = ralt.scale(1.0 / tau.max(1.0));
        let a = self.right_projection(seed, m);
        let p = self.left_probe(seed, n);
        // A-step: (P Pᵀ + εI) A₁ = r̄
        let ppt = p.matmul_nt(&p);
        let a1 = solve_ridge(&ppt, &r_mean)?;
        // B-step: B₁ (A₁ Aᵀ) = c̄  ⇔  (A₁ Aᵀ)ᵀ B₁ᵀ = c̄ᵀ
        let s = a1.matmul_nt(&a);
        let b1t = solve_ridge(&s.transpose(), &c_mean.transpose())?;
        Ok(b1t.transpose().matmul(&a1))
    }

    /// Cycle end: reconstruct the mean-gradient estimate and hand it to
    /// the base optimizer. The caller zeroes both sketches afterwards
    /// (the trainer's Method-group zero covers them together).
    #[allow(clippy::too_many_arguments)]
    pub fn apply_accumulated(
        &self,
        param: &mut Matrix,
        acc: &Matrix,
        ralt: &Matrix,
        opt_state: &mut [Matrix],
        seed: u64,
        tau: f32,
        lr: f32,
        step: f32,
    ) -> Result<(), String> {
        let ghat = self.estimate(acc, ralt, seed, tau)?;
        self.base.update(param, &ghat, opt_state, lr, step)
    }

    /// Fused τ=1 path (the ViT catalog steps): sketch the fresh gradient
    /// and reconstruct in one call, no persistent method state.
    pub fn estimate_from_grad(&self, grad: &Matrix, seed: u64) -> Result<Matrix, String> {
        let mut acc = Matrix::zeros(grad.rows, self.rank);
        let mut ralt = Matrix::zeros(self.rank, grad.cols);
        self.accumulate(&mut acc, &mut ralt, grad, seed);
        self.estimate(&acc, &ralt, seed, 1.0)
    }
}

/// Solve `(S + εI) X = RHS` for `X ∈ R^{r×k}` by Gaussian elimination
/// with partial pivoting; `ε` is [`RIDGE_EPS`] times the mean absolute
/// diagonal of `S` (plus a tiny absolute floor), which regularizes both
/// the SPD A-step and the general B-step without washing out
/// well-conditioned solves.
fn solve_ridge(s: &Matrix, rhs: &Matrix) -> Result<Matrix, String> {
    let r = s.rows;
    if s.cols != r || rhs.rows != r {
        return Err(format!(
            "solve_ridge: S is {:?}, rhs is {:?} (want square S, matching rows)",
            s.shape(),
            rhs.shape()
        ));
    }
    let diag_mean: f32 =
        (0..r).map(|i| s.at(i, i).abs()).sum::<f32>() / r.max(1) as f32;
    let ridge = RIDGE_EPS * diag_mean + 1e-12;
    let k = rhs.cols;
    let mut a: Vec<f32> = Vec::with_capacity(r * r);
    for i in 0..r {
        for j in 0..r {
            a.push(s.at(i, j) + if i == j { ridge } else { 0.0 });
        }
    }
    let mut x: Vec<f32> = rhs.data.clone();
    for col in 0..r {
        // partial pivot on the largest remaining magnitude in this column
        let mut piv = col;
        let mut best = a[col * r + col].abs();
        for row in (col + 1)..r {
            let v = a[row * r + col].abs();
            if v > best {
                best = v;
                piv = row;
            }
        }
        if best < 1e-20 {
            return Err(format!(
                "solve_ridge: pivot collapse at column {col} (|pivot|={best:e})"
            ));
        }
        if piv != col {
            for j in 0..r {
                a.swap(col * r + j, piv * r + j);
            }
            for j in 0..k {
                x.swap(col * k + j, piv * k + j);
            }
        }
        let inv = 1.0 / a[col * r + col];
        for row in (col + 1)..r {
            let f = a[row * r + col] * inv;
            if f == 0.0 {
                continue;
            }
            for j in col..r {
                a[row * r + j] -= f * a[col * r + j];
            }
            for j in 0..k {
                x[row * k + j] -= f * x[col * k + j];
            }
        }
    }
    for col in (0..r).rev() {
        let inv = 1.0 / a[col * r + col];
        for j in 0..k {
            let mut v = x[col * k + j];
            for jj in (col + 1)..r {
                v -= a[col * r + jj] * x[jj * k + j];
            }
            x[col * k + j] = v * inv;
        }
    }
    Ok(Matrix::from_vec(r, k, x))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::opt::base::Sgd;
    use crate::util::rng::Rng;

    fn randn(seed: u64, n: usize, m: usize) -> Matrix {
        let mut rng = Rng::new(seed);
        Matrix::gaussian(n, m, 1.0, &mut rng)
    }

    /// A rank-`r` matrix with generic factors.
    fn lowrank(seed: u64, n: usize, m: usize, r: usize) -> Matrix {
        randn(seed, n, r).matmul(&randn(seed + 1, r, m))
    }

    #[test]
    fn solve_ridge_recovers_known_solution() {
        // S X = S X₀ must return ≈ X₀ for a well-conditioned S
        let x0 = randn(0, 6, 3);
        let mut s = randn(1, 6, 6).scale(0.1);
        for i in 0..6 {
            *s.at_mut(i, i) += 3.0; // diagonally dominant
        }
        let rhs = s.matmul(&x0);
        let x = solve_ridge(&s, &rhs).unwrap();
        assert!(x.allclose(&x0, 1e-2), "max dev {}", (&x - &x0).max_abs());
    }

    #[test]
    fn solve_ridge_rejects_shape_mismatch() {
        assert!(solve_ridge(&Matrix::zeros(3, 4), &Matrix::zeros(3, 2)).is_err());
        assert!(solve_ridge(&randn(2, 4, 4), &Matrix::zeros(3, 2)).is_err());
    }

    #[test]
    fn accumulate_is_linear_in_the_gradient() {
        let comp = AltLoraCompressor::new(Sgd, 4);
        let g1 = randn(3, 8, 24);
        let g2 = randn(4, 8, 24);
        let seed = 77;
        let mut acc = Matrix::zeros(8, 4);
        let mut ralt = Matrix::zeros(4, 24);
        comp.accumulate(&mut acc, &mut ralt, &g1, seed);
        comp.accumulate(&mut acc, &mut ralt, &g2, seed);
        let mut sum = g1.clone();
        sum.add_scaled_inplace(&g2, 1.0);
        let mut acc2 = Matrix::zeros(8, 4);
        let mut ralt2 = Matrix::zeros(4, 24);
        comp.accumulate(&mut acc2, &mut ralt2, &sum, seed);
        assert!(acc.allclose(&acc2, 1e-4));
        assert!(ralt.allclose(&ralt2, 1e-4));
    }

    #[test]
    fn left_and_right_sketch_seeds_differ() {
        let comp = AltLoraCompressor::new(Sgd, 4);
        let a = comp.right_projection(9, 16);
        let p = comp.left_probe(9, 16);
        assert!(!a.allclose(&p, 1e-3));
    }

    #[test]
    fn exact_recovery_of_low_rank_gradients() {
        // rank(Ḡ) <= r ⇒ the alternating-projection estimate is exact
        let comp = AltLoraCompressor::new(Sgd, 4);
        let g = lowrank(10, 12, 20, 3);
        let ghat = comp.estimate_from_grad(&g, 55).unwrap();
        let rel = (&ghat - &g).frobenius_norm() / g.frobenius_norm();
        assert!(rel < 0.02, "relative error {rel}");
    }

    #[test]
    fn beats_flora_reconstruction_on_low_rank_gradients() {
        let comp = AltLoraCompressor::new(Sgd, 4);
        let g = lowrank(20, 12, 20, 4);
        let mut alt_err = 0.0f32;
        let mut flora_err = 0.0f32;
        for s in 0..8u64 {
            let ghat = comp.estimate_from_grad(&g, 100 + s).unwrap();
            alt_err += (&ghat - &g).frobenius_norm();
            flora_err += (&rp::project_gradient(&g, 100 + s, 4) - &g).frobenius_norm();
        }
        assert!(
            alt_err < 0.2 * flora_err,
            "alt {alt_err} vs flora {flora_err}"
        );
    }

    #[test]
    fn estimate_reproduces_the_right_sketch() {
        // B-step consistency: Ĝ Aᵀ == c̄ by construction
        let comp = AltLoraCompressor::new(Sgd, 4);
        let g = randn(30, 10, 18);
        let seed = 42;
        let mut acc = Matrix::zeros(10, 4);
        let mut ralt = Matrix::zeros(4, 18);
        for _ in 0..3 {
            comp.accumulate(&mut acc, &mut ralt, &g, seed);
        }
        let ghat = comp.estimate(&acc, &ralt, seed, 3.0).unwrap();
        let a = comp.right_projection(seed, 18);
        let c_mean = acc.scale(1.0 / 3.0);
        let back = ghat.matmul_nt(&a);
        let rel = (&back - &c_mean).frobenius_norm() / c_mean.frobenius_norm();
        assert!(rel < 0.01, "sketch consistency error {rel}");
    }

    #[test]
    fn apply_accumulated_with_sgd_matches_manual_estimate() {
        let comp = AltLoraCompressor::new(Sgd, 4);
        let g = randn(40, 8, 16);
        let seed = 13;
        let mut acc = Matrix::zeros(8, 4);
        let mut ralt = Matrix::zeros(4, 16);
        comp.accumulate(&mut acc, &mut ralt, &g, seed);
        let mut w = randn(41, 8, 16);
        let mut want = w.clone();
        let mut st = Vec::new();
        comp.apply_accumulated(&mut w, &acc, &ralt, &mut st, seed, 1.0, 0.5, 0.0)
            .unwrap();
        let ghat = comp.estimate(&acc, &ralt, seed, 1.0).unwrap();
        want.add_scaled_inplace(&ghat, -0.5);
        assert!(w.allclose(&want, 1e-5));
    }

    #[test]
    fn estimate_is_deterministic_per_seed() {
        let comp = AltLoraCompressor::new(Sgd, 4);
        let g = randn(50, 8, 16);
        let a = comp.estimate_from_grad(&g, 7).unwrap();
        let b = comp.estimate_from_grad(&g, 7).unwrap();
        let ba: Vec<u32> = a.data.iter().map(|x| x.to_bits()).collect();
        let bb: Vec<u32> = b.data.iter().map(|x| x.to_bits()).collect();
        assert_eq!(ba, bb);
        let c = comp.estimate_from_grad(&g, 8).unwrap();
        assert!(!a.allclose(&c, 1e-4));
    }
}
