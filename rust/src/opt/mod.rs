//! First-class optimizer API: pluggable base optimizers + the FLORA
//! gradient compressor.
//!
//! FLORA's core claim is that LoRA-style updates are secretly *gradient
//! compression* — which means FLORA should compose with any base
//! optimizer, not live hard-coded inside fused training steps. This module
//! is that composition surface:
//!
//! * [`BaseOptimizer`] — the update-rule trait (`init_state` /
//!   `state_shapes` / `update`), with three backend-free implementations
//!   over [`crate::tensor::Matrix`]: [`Sgd`], [`Adam`] (bias-corrected
//!   m/v) and [`Adafactor`] (factored row/col second moments — the
//!   paper's Table-1/2 base optimizer; `Adafactor::unfactored()` is the
//!   Table-4 linear-memory ablation).
//! * [`FloraCompressor`] — Algorithms 1 and 2 over any `BaseOptimizer`:
//!   per-parameter seed lifecycle, compressed accumulation
//!   (`C += G Aᵀ`), cycle-end decompress-and-update, and
//!   momentum-in-subspace with κ-resample transfer.
//! * [`AltLoraCompressor`] — alternating-projection compression: dual
//!   seeded sketches and a best rank-r reconstruction solve instead of
//!   the fixed-projection read-back (the `altlora` compressor variant).
//! * [`RankSchedule`] / [`ScheduledFlora`] — adaptive-rank control: the
//!   momentum subspace shrinks at cycle boundaries with bit-exact state
//!   migration and byte accounting (the `adarank` compressor variant).
//! * [`OptimizerKind`] — the typed config/CLI surface
//!   (`--optimizer sgd|adam|adafactor|adafactor_nofactor`) that the
//!   native catalog and the AOT manifest names both key on.
//! * [`CompressorKind`] — the `--compressor flora|altlora|adarank`
//!   selector mapping a flora-family method onto one of the three
//!   compression algebras above.
//!
//! The semantics mirror `python/compile/optimizers.py` and
//! `python/compile/flora.py` (the L2 half of the ABI contract), so the
//! native backend's fused steps and the AOT graphs compute the same
//! updates.
//!
//! # Example: a full Algorithm-1 cycle on a rank-4 compressor
//!
//! ```
//! use flora::opt::{Adafactor, BaseOptimizer, FloraCompressor};
//! use flora::tensor::Matrix;
//!
//! let flora = FloraCompressor::new(Adafactor::new(), 4);
//! let mut w = Matrix::zeros(8, 8);
//! let mut opt_state = flora.base().init_state(8, 8);
//! let mut acc = Matrix::zeros(8, 4); // compressed accumulator [n, r]
//!
//! let g = Matrix::from_fn(8, 8, |i, j| ((i * 8 + j) % 5) as f32 * 0.01);
//! let seed = flora.param_seed(42, 0); // cycle seed 42, parameter 0
//! for _ in 0..4 {
//!     flora.accumulate(&mut acc, &g, seed); // C += G Aᵀ (Alg. 1 line 9)
//! }
//! // cycle end: decompress the mean gradient, base-optimizer step
//! flora
//!     .apply_accumulated(&mut w, &acc, &mut opt_state, seed, 4.0, 0.1, 0.0)
//!     .unwrap();
//! assert!(w.frobenius_norm() > 0.0);
//! ```

pub mod altlora;
pub mod base;
pub mod flora;
pub mod schedule;

pub use self::altlora::AltLoraCompressor;
pub use self::base::{Adafactor, Adam, BaseOptimizer, Sgd};
pub use self::flora::{FloraCompressor, SubspaceTick, MOMENTUM_BETA};
pub use self::schedule::{
    migrate, migrate_in_place, reclaimed_bytes, RankSchedule, RankedTick,
    ScheduledFlora,
};

/// The compressor family selector wired through `--compressor` and
/// `[train] compressor`: which accumulate/apply algebra runs on top of
/// the flora-family rank-r method state.
///
/// * `flora` — Algorithms 1–2 (seeded random projection, the baseline)
/// * `altlora` — alternating-projection reconstruction
///   ([`AltLoraCompressor`], dual sketches, best rank-r solve)
/// * `adarank` — Algorithm-2 momentum under an adaptive
///   [`RankSchedule`] ([`ScheduledFlora`], shrink-and-migrate)
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CompressorKind {
    Flora,
    AltLora,
    AdaRank,
}

impl CompressorKind {
    pub const ALL: [CompressorKind; 3] =
        [CompressorKind::Flora, CompressorKind::AltLora, CompressorKind::AdaRank];

    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "flora" => Ok(CompressorKind::Flora),
            "altlora" => Ok(CompressorKind::AltLora),
            "adarank" => Ok(CompressorKind::AdaRank),
            _ => Err(format!(
                "unknown compressor {s:?} (want flora|altlora|adarank)"
            )),
        }
    }

    /// The ABI tag used in catalog executable names (`*_altlora`, ...).
    pub fn name(self) -> &'static str {
        match self {
            CompressorKind::Flora => "flora",
            CompressorKind::AltLora => "altlora",
            CompressorKind::AdaRank => "adarank",
        }
    }
}

impl std::fmt::Display for CompressorKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// The optimizer selector wired through config, the CLI and the catalog
/// naming scheme (`{model}/plain_step_{optimizer}`, ...).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum OptimizerKind {
    Sgd,
    Adam,
    Adafactor,
    /// Adafactor with a full (unfactored) second moment — the paper's
    /// Table-4 "optimizer with linear memory" ablation.
    AdafactorNoFactor,
}

impl OptimizerKind {
    pub const ALL: [OptimizerKind; 4] = [
        OptimizerKind::Sgd,
        OptimizerKind::Adam,
        OptimizerKind::Adafactor,
        OptimizerKind::AdafactorNoFactor,
    ];

    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "sgd" => Ok(OptimizerKind::Sgd),
            "adam" => Ok(OptimizerKind::Adam),
            "adafactor" => Ok(OptimizerKind::Adafactor),
            "adafactor_nofactor" => Ok(OptimizerKind::AdafactorNoFactor),
            _ => Err(format!(
                "unknown optimizer {s:?} (want \
                 sgd|adam|adafactor|adafactor_nofactor)"
            )),
        }
    }

    /// The ABI name used in manifest executable names.
    pub fn name(self) -> &'static str {
        match self {
            OptimizerKind::Sgd => "sgd",
            OptimizerKind::Adam => "adam",
            OptimizerKind::Adafactor => "adafactor",
            OptimizerKind::AdafactorNoFactor => "adafactor_nofactor",
        }
    }

    /// Instantiate the optimizer with its paper-default hyperparameters.
    pub fn build(self) -> Box<dyn BaseOptimizer> {
        match self {
            OptimizerKind::Sgd => Box::new(Sgd),
            OptimizerKind::Adam => Box::new(Adam::new()),
            OptimizerKind::Adafactor => Box::new(Adafactor::new()),
            OptimizerKind::AdafactorNoFactor => Box::new(Adafactor::unfactored()),
        }
    }
}

impl std::fmt::Display for OptimizerKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_name_roundtrip() {
        for kind in OptimizerKind::ALL {
            assert_eq!(OptimizerKind::parse(kind.name()).unwrap(), kind);
            assert_eq!(kind.build().name(), kind.name());
        }
        assert!(OptimizerKind::parse("adamw").is_err());
    }

    #[test]
    fn compressor_parse_name_roundtrip() {
        for kind in CompressorKind::ALL {
            assert_eq!(CompressorKind::parse(kind.name()).unwrap(), kind);
            assert_eq!(kind.to_string(), kind.name());
        }
        assert!(CompressorKind::parse("galore").is_err());
    }

    #[test]
    fn display_matches_abi_name() {
        assert_eq!(OptimizerKind::Adafactor.to_string(), "adafactor");
        assert_eq!(
            OptimizerKind::AdafactorNoFactor.to_string(),
            "adafactor_nofactor"
        );
    }

    #[test]
    fn built_optimizers_have_expected_state_arity() {
        assert_eq!(OptimizerKind::Sgd.build().state_shapes(4, 4).len(), 0);
        assert_eq!(OptimizerKind::Adam.build().state_shapes(4, 4).len(), 2);
        assert_eq!(OptimizerKind::Adafactor.build().state_shapes(4, 4).len(), 2);
        assert_eq!(
            OptimizerKind::AdafactorNoFactor.build().state_shapes(4, 4).len(),
            1
        );
    }
}
