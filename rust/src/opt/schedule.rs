//! `RankSchedule` — adaptive-rank control over any subspace compressor.
//!
//! AdaRankGrad's observation (PAPERS.md) is that the gradient's effective
//! rank shrinks as training converges, so a compressor can reclaim its
//! state budget on the fly: shrink the projected rank at cycle
//! boundaries, truncate the subspace coordinates that die, and account
//! the bytes handed back. This module owns that lifecycle:
//!
//! * [`RankSchedule`] — the typed schedule knob
//!   (`fixed` / `linear-decay:N` / `halve-at:N`), mapping a resample
//!   cycle index to an active rank. Monotone nonincreasing, floored at 1.
//! * [`migrate`] / [`migrate_in_place`] — explicit state migration on a
//!   shrink: the retained subspace rows survive **bit-exactly** (they are
//!   a prefix of the projected coordinates), the dropped rows are
//!   reclaimed, and the reclaimed bytes match [`reclaimed_bytes`].
//! * [`ScheduledFlora`] — the Algorithm-2 momentum step generalized to a
//!   ranked subspace: projections come from the *master-rank* sampling
//!   law ([`crate::rp::projection_sub`]), so a rank-`ra` projection is a
//!   bit-exact prefix of the rank-`r0` one and shrinking never perturbs
//!   the retained coordinates.
//!
//! The fused native catalog keeps the momentum tensor at its static
//! master shape `[n, r0]` and zeroes the truncated columns instead of
//! reallocating (the manifest ABI is shape-stable); the analytic
//! accountant still books the reclaimed bytes via [`reclaimed_bytes`].

use super::base::BaseOptimizer;
use super::flora::{FloraCompressor, SubspaceTick};
use crate::rp;
use crate::tensor::Matrix;

/// When during training the compressor's rank shrinks. The unit of time
/// is the *resample cycle* (the κ-interval index), never the raw step,
/// so a schedule composes with any κ.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RankSchedule {
    /// Rank stays at r0 forever (the Flora Algorithm-2 baseline).
    Fixed,
    /// Rank drops by 1 every `every` cycles: r(c) = r0 − c/every.
    LinearDecay { every: usize },
    /// Rank halves every `every` cycles: r(c) = r0 >> (c/every).
    HalveAt { every: usize },
}

impl Default for RankSchedule {
    fn default() -> Self {
        RankSchedule::Fixed
    }
}

impl RankSchedule {
    /// Parse the config/CLI spelling: `fixed`, `linear-decay:N`,
    /// `halve-at:N` (N = cycles between shrinks, >= 1).
    pub fn parse(s: &str) -> Result<Self, String> {
        if s == "fixed" {
            return Ok(RankSchedule::Fixed);
        }
        let every_of = |spec: &str, tag: &str| -> Result<usize, String> {
            let n: usize = spec.parse().map_err(|_| {
                format!("rank schedule {tag}:{spec:?}: want a positive cycle count")
            })?;
            if n == 0 {
                return Err(format!("rank schedule {tag}:0: cycle count must be >= 1"));
            }
            Ok(n)
        };
        match s.split_once(':') {
            Some(("linear-decay", n)) => {
                Ok(RankSchedule::LinearDecay { every: every_of(n, "linear-decay")? })
            }
            Some(("halve-at", n)) => {
                Ok(RankSchedule::HalveAt { every: every_of(n, "halve-at")? })
            }
            _ => Err(format!(
                "unknown rank schedule {s:?} (want fixed|linear-decay:N|halve-at:N)"
            )),
        }
    }

    /// The config/CLI spelling this schedule parses back from.
    pub fn name(&self) -> String {
        match self {
            RankSchedule::Fixed => "fixed".into(),
            RankSchedule::LinearDecay { every } => format!("linear-decay:{every}"),
            RankSchedule::HalveAt { every } => format!("halve-at:{every}"),
        }
    }

    pub fn is_fixed(&self) -> bool {
        matches!(self, RankSchedule::Fixed)
    }

    /// Active rank at resample-cycle `cycle` starting from master rank
    /// `r0`. Monotone nonincreasing in `cycle`, never below 1, never
    /// above `r0`.
    pub fn rank_at(&self, r0: usize, cycle: usize) -> usize {
        let r = match self {
            RankSchedule::Fixed => r0,
            RankSchedule::LinearDecay { every } => {
                r0.saturating_sub(cycle / every)
            }
            RankSchedule::HalveAt { every } => {
                let halvings = (cycle / every).min(63);
                r0 >> halvings
            }
        };
        r.clamp(1, r0.max(1))
    }
}

/// Bytes handed back when a `[n, r_old]` subspace state shrinks to
/// `rank_new` coordinates: `(r_old − rank_new) · n · 4`.
pub fn reclaimed_bytes(n: usize, rank_old: usize, rank_new: usize) -> u64 {
    (rank_old.saturating_sub(rank_new) as u64) * n as u64 * 4
}

/// Shrink a projected-subspace state `[n, r_old]` to its first
/// `rank_new` coordinates. The retained columns are copied bit-exactly;
/// the return pairs the migrated `[n, rank_new]` state with the
/// reclaimed bytes (exactly [`reclaimed_bytes`]).
pub fn migrate(state: &Matrix, rank_new: usize) -> Result<(Matrix, u64), String> {
    let (n, r_old) = state.shape();
    if rank_new == 0 || rank_new > r_old {
        return Err(format!(
            "rank migration: new rank {rank_new} outside 1..={r_old}"
        ));
    }
    let kept = Matrix::from_fn(n, rank_new, |i, j| state.at(i, j));
    Ok((kept, reclaimed_bytes(n, r_old, rank_new)))
}

/// [`migrate`] for the fused catalog's shape-stable ABI: the tensor
/// keeps its master `[n, r0]` shape and every coordinate at column
/// >= `rank_new` is zeroed in place. Returns the bytes the analytic
/// accountant books as reclaimed (`rank_active` = the rank live before
/// the shrink).
pub fn migrate_in_place(state: &mut Matrix, rank_active: usize, rank_new: usize) -> u64 {
    let (n, r0) = state.shape();
    for i in 0..n {
        for j in rank_new..r0 {
            *state.at_mut(i, j) = 0.0;
        }
    }
    reclaimed_bytes(n, rank_active.min(r0), rank_new)
}

/// One ranked Algorithm-2 tick: the seed schedule plus the active ranks
/// on each side of a (possible) resample boundary. On non-resample steps
/// `rank_cur == rank_next`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RankedTick {
    pub sub: SubspaceTick,
    /// Rank the momentum lives at BEFORE this step.
    pub rank_cur: usize,
    /// Rank after this step (may shrink only on resample boundaries).
    pub rank_next: usize,
}

/// The AdaRank compressor: a [`FloraCompressor`] whose momentum subspace
/// shrinks under a [`RankSchedule`]. `rank()` of the inner compressor is
/// the *master* rank r0 — state tensors are sized for it — while each
/// step runs at the tick's active rank with master-law projections.
#[derive(Clone, Debug)]
pub struct ScheduledFlora<O> {
    flora: FloraCompressor<O>,
    schedule: RankSchedule,
}

impl<O: BaseOptimizer> ScheduledFlora<O> {
    pub fn new(flora: FloraCompressor<O>, schedule: RankSchedule) -> Self {
        Self { flora, schedule }
    }

    pub fn flora(&self) -> &FloraCompressor<O> {
        &self.flora
    }

    pub fn schedule(&self) -> RankSchedule {
        self.schedule
    }

    /// Master rank r0 (the allocated state width).
    pub fn master_rank(&self) -> usize {
        self.flora.rank()
    }

    /// Active rank at resample-cycle `cycle`.
    pub fn rank_at(&self, cycle: usize) -> usize {
        self.schedule.rank_at(self.master_rank(), cycle)
    }

    /// Sub-rank projection at the master sampling law: the first `ra`
    /// rows of the master rank-r0 projection, bit-exact.
    pub fn projection_at(&self, seed: u64, ra: usize, m: usize) -> Matrix {
        rp::projection_sub(seed, ra, self.master_rank(), m)
    }

    /// One ranked momentum step over a shape-stable `[n, r0]` momentum
    /// tensor. Order on a shrinking resample boundary: truncate the
    /// subspace coordinates to `rank_next` FIRST (the retained prefix is
    /// bit-exact), then transfer the survivors into the next subspace.
    /// Returns the bytes reclaimed by the truncation (0 off boundaries).
    ///
    /// The decompressed effective gradient is scaled by `r0/ra` — the
    /// sub-projection's Gram matrix has expectation `(ra/r0)·I` under the
    /// master sampling law, so the compensation keeps the update unbiased
    /// at every active rank.
    #[allow(clippy::too_many_arguments)]
    pub fn momentum_step(
        &self,
        param: &mut Matrix,
        mom: &mut Matrix,
        opt_state: &mut [Matrix],
        grad: &Matrix,
        tick: RankedTick,
        lr: f32,
        step: f32,
    ) -> Result<u64, String> {
        let r0 = self.master_rank();
        let m_dim = param.cols;
        if mom.cols != r0 {
            return Err(format!(
                "ranked momentum: state has {} columns, master rank is {r0}",
                mom.cols
            ));
        }
        if tick.rank_cur > r0 || tick.rank_next > tick.rank_cur || tick.rank_next == 0 {
            return Err(format!(
                "ranked momentum: ranks {}->{} invalid under master rank {r0}",
                tick.rank_cur, tick.rank_next
            ));
        }
        let ra = if tick.sub.resample { tick.rank_next } else { tick.rank_cur };
        let mut reclaimed = 0u64;
        if tick.sub.resample {
            if tick.rank_next < tick.rank_cur {
                reclaimed = migrate_in_place(mom, tick.rank_cur, tick.rank_next);
            }
            if tick.sub.transfer {
                let a_old = self.projection_at(tick.sub.seed_cur, ra, m_dim);
                let a_new = self.projection_at(tick.sub.seed_next, ra, m_dim);
                let (active, _) = migrate(mom, ra)?;
                let moved = rp::transfer(&active, &a_old, &a_new);
                write_active(mom, &moved);
            }
        }
        let a = self.projection_at(tick.sub.active_seed(), ra, m_dim);
        let c = rp::compress(grad, &a);
        // EMA only the live coordinates; truncated columns stay zero
        let beta = self.flora.beta();
        let (mut active, _) = migrate(mom, ra)?;
        let mut next = active.scale(beta);
        next.add_scaled_inplace(&c, 1.0 - beta);
        active = next;
        write_active(mom, &active);
        let eff = rp::decompress(&active, &a).scale(r0 as f32 / ra as f32);
        self.flora.base().update(param, &eff, opt_state, lr, step)?;
        Ok(reclaimed)
    }
}

/// Write the `[n, ra]` active block back into the `[n, r0]` master
/// tensor, zeroing columns >= ra.
fn write_active(master: &mut Matrix, active: &Matrix) {
    let (n, r0) = master.shape();
    let ra = active.cols;
    for i in 0..n {
        for j in 0..r0 {
            *master.at_mut(i, j) = if j < ra { active.at(i, j) } else { 0.0 };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::opt::base::Sgd;
    use crate::util::rng::Rng;

    fn randn(seed: u64, n: usize, m: usize) -> Matrix {
        let mut rng = Rng::new(seed);
        Matrix::gaussian(n, m, 1.0, &mut rng)
    }

    #[test]
    fn parse_name_roundtrip() {
        for s in ["fixed", "linear-decay:3", "halve-at:2"] {
            let sched = RankSchedule::parse(s).unwrap();
            assert_eq!(sched.name(), s);
        }
        assert!(RankSchedule::parse("halve-at:0").is_err());
        assert!(RankSchedule::parse("linear-decay:x").is_err());
        assert!(RankSchedule::parse("cosine").is_err());
    }

    #[test]
    fn schedules_are_monotone_and_floored() {
        for sched in [
            RankSchedule::Fixed,
            RankSchedule::LinearDecay { every: 2 },
            RankSchedule::HalveAt { every: 3 },
        ] {
            let mut last = usize::MAX;
            for cycle in 0..200 {
                let r = sched.rank_at(16, cycle);
                assert!(r >= 1 && r <= 16, "{sched:?} cycle {cycle}: {r}");
                assert!(r <= last, "{sched:?} not monotone at cycle {cycle}");
                last = r;
            }
        }
        assert_eq!(RankSchedule::Fixed.rank_at(8, 999), 8);
        assert_eq!(RankSchedule::HalveAt { every: 1 }.rank_at(8, 2), 2);
        assert_eq!(RankSchedule::LinearDecay { every: 1 }.rank_at(4, 10), 1);
    }

    #[test]
    fn migrate_keeps_prefix_bit_exact_and_accounts_bytes() {
        let state = randn(0, 6, 8);
        let (kept, freed) = migrate(&state, 3).unwrap();
        assert_eq!(kept.shape(), (6, 3));
        assert_eq!(freed, reclaimed_bytes(6, 8, 3));
        assert_eq!(freed, 5 * 6 * 4);
        for i in 0..6 {
            for j in 0..3 {
                assert_eq!(kept.at(i, j).to_bits(), state.at(i, j).to_bits());
            }
        }
        assert!(migrate(&state, 0).is_err());
        assert!(migrate(&state, 9).is_err());
    }

    #[test]
    fn migrate_in_place_zeroes_dead_columns() {
        let mut state = randn(1, 5, 8);
        let before = state.clone();
        let freed = migrate_in_place(&mut state, 8, 2);
        assert_eq!(freed, reclaimed_bytes(5, 8, 2));
        for i in 0..5 {
            for j in 0..8 {
                if j < 2 {
                    assert_eq!(state.at(i, j).to_bits(), before.at(i, j).to_bits());
                } else {
                    assert_eq!(state.at(i, j), 0.0);
                }
            }
        }
    }

    #[test]
    fn fixed_schedule_full_rank_matches_flora_momentum_bitwise() {
        // at ra == r0 the ranked step IS Algorithm 2: the sub-projection
        // equals the full projection and the r0/ra compensation is 1
        let comp = FloraCompressor::new(Sgd, 4);
        let sched = ScheduledFlora::new(comp.clone(), RankSchedule::Fixed);
        let g = randn(2, 6, 16);
        for (resample, transfer) in [(false, true), (true, true)] {
            let sub = SubspaceTick { seed_cur: 5, seed_next: 6, resample, transfer };
            let mut w1 = randn(3, 6, 16);
            let mut m1 = randn(4, 6, 4).scale(0.1);
            let mut s1 = Vec::new();
            comp.momentum_step(&mut w1, &mut m1, &mut s1, &g, sub, 0.1, 0.0).unwrap();

            let mut w2 = randn(3, 6, 16);
            let mut m2 = randn(4, 6, 4).scale(0.1);
            let mut s2 = Vec::new();
            let tick = RankedTick { sub, rank_cur: 4, rank_next: 4 };
            let freed = sched
                .momentum_step(&mut w2, &mut m2, &mut s2, &g, tick, 0.1, 0.0)
                .unwrap();
            assert_eq!(freed, 0);
            let b = |m: &Matrix| m.data.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            assert_eq!(b(&w1), b(&w2), "resample={resample}");
            assert_eq!(b(&m1), b(&m2), "mom resample={resample}");
        }
    }

    #[test]
    fn shrinking_step_truncates_then_transfers_and_reports_bytes() {
        let sched = ScheduledFlora::new(
            FloraCompressor::new(Sgd, 8),
            RankSchedule::HalveAt { every: 1 },
        );
        let g = randn(7, 6, 16);
        let mut w = randn(8, 6, 16);
        let mut mom = randn(9, 6, 8).scale(0.1);
        let mut st = Vec::new();
        let tick = RankedTick {
            sub: SubspaceTick { seed_cur: 20, seed_next: 21, resample: true, transfer: true },
            rank_cur: 8,
            rank_next: 4,
        };
        let freed =
            sched.momentum_step(&mut w, &mut mom, &mut st, &g, tick, 0.1, 0.0).unwrap();
        assert_eq!(freed, reclaimed_bytes(6, 8, 4));
        // dead columns must be exactly zero after the step
        for i in 0..6 {
            for j in 4..8 {
                assert_eq!(mom.at(i, j), 0.0, "({i},{j})");
            }
        }
        assert!(w.frobenius_norm() > 0.0);
    }

    #[test]
    fn invalid_ranks_are_loud() {
        let sched = ScheduledFlora::new(FloraCompressor::new(Sgd, 4), RankSchedule::Fixed);
        let g = randn(10, 4, 8);
        let mut w = randn(11, 4, 8);
        let mut mom = Matrix::zeros(4, 4);
        let mut st = Vec::new();
        let sub = SubspaceTick { seed_cur: 1, seed_next: 2, resample: false, transfer: true };
        for (rc, rn) in [(5, 4), (4, 0), (2, 3)] {
            let tick = RankedTick { sub, rank_cur: rc, rank_next: rn };
            assert!(
                sched.momentum_step(&mut w, &mut mom, &mut st, &g, tick, 0.1, 0.0).is_err(),
                "{rc}->{rn}"
            );
        }
    }
}
