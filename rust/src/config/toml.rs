//! TOML-subset parser: tables `[a.b]`, key/value pairs with string, integer,
//! float, boolean and flat-array values, comments, and dotted keys inside
//! values' tables flattened to `a.b.key` paths. Enough for experiment
//! configs; errors carry line numbers.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[TomlValue]> {
        match self {
            TomlValue::Array(v) => Some(v),
            _ => None,
        }
    }
}

#[derive(Debug)]
pub struct TomlError {
    pub line: usize,
    pub msg: String,
}

impl fmt::Display for TomlError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "toml error on line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for TomlError {}

/// Parse into a flat `section.key -> value` map.
pub fn parse_toml(input: &str) -> Result<BTreeMap<String, TomlValue>, TomlError> {
    let mut out = BTreeMap::new();
    let mut section = String::new();
    for (lineno, raw) in input.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        let err = |msg: &str| TomlError { line: lineno + 1, msg: msg.to_string() };
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .ok_or_else(|| err("unterminated table header"))?;
            if name.is_empty() {
                return Err(err("empty table name"));
            }
            // Quoted segments (`[a.floors."x/y"]`) carry names with
            // TOML-special chars; drop the quotes so flat keys read
            // `a.floors.x/y.key` — matching the raw names consumers use.
            section = name.trim().replace('"', "");
            continue;
        }
        let eq = line.find('=').ok_or_else(|| err("expected key = value"))?;
        let key = line[..eq].trim();
        if key.is_empty() {
            return Err(err("empty key"));
        }
        let value = parse_value(line[eq + 1..].trim())
            .map_err(|m| err(&m))?;
        let full = if section.is_empty() {
            key.to_string()
        } else {
            format!("{section}.{key}")
        };
        if out.insert(full.clone(), value).is_some() {
            return Err(err(&format!("duplicate key {full:?}")));
        }
    }
    Ok(out)
}

fn strip_comment(line: &str) -> &str {
    // a '#' outside quotes starts a comment
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner
            .strip_suffix('"')
            .ok_or_else(|| "unterminated string".to_string())?;
        return Ok(TomlValue::Str(inner.replace("\\\"", "\"").replace("\\\\", "\\")));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| "unterminated array".to_string())?
            .trim();
        if inner.is_empty() {
            return Ok(TomlValue::Array(vec![]));
        }
        let items = split_top_level(inner)?;
        return Ok(TomlValue::Array(
            items
                .iter()
                .map(|it| parse_value(it.trim()))
                .collect::<Result<_, _>>()?,
        ));
    }
    if s.contains('.') || s.contains('e') || s.contains('E') {
        if let Ok(f) = s.parse::<f64>() {
            return Ok(TomlValue::Float(f));
        }
    }
    if let Ok(i) = s.replace('_', "").parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(format!("cannot parse value {s:?}"))
}

fn split_top_level(s: &str) -> Result<Vec<String>, String> {
    let mut out = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut cur = String::new();
    for c in s.chars() {
        match c {
            '"' => {
                in_str = !in_str;
                cur.push(c);
            }
            '[' if !in_str => {
                depth += 1;
                cur.push(c);
            }
            ']' if !in_str => {
                depth = depth.checked_sub(1).ok_or("unbalanced brackets")?;
                cur.push(c);
            }
            ',' if !in_str && depth == 0 => {
                out.push(std::mem::take(&mut cur));
            }
            _ => cur.push(c),
        }
    }
    if !cur.trim().is_empty() {
        out.push(cur);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_scalars() {
        let doc = r#"
            # experiment config
            name = "table1"
            [train]
            steps = 200
            lr = 5e-2
            flora = true
            ranks = [4, 8, 16, 32]
        "#;
        let m = parse_toml(doc).unwrap();
        assert_eq!(m["name"].as_str(), Some("table1"));
        assert_eq!(m["train.steps"].as_i64(), Some(200));
        assert_eq!(m["train.lr"].as_f64(), Some(0.05));
        assert_eq!(m["train.flora"].as_bool(), Some(true));
        assert_eq!(m["train.ranks"].as_array().unwrap().len(), 4);
    }

    #[test]
    fn comments_and_hash_in_string() {
        let m = parse_toml(r##"s = "a#b" # trailing"##).unwrap();
        assert_eq!(m["s"].as_str(), Some("a#b"));
    }

    #[test]
    fn int_with_underscores_and_negative() {
        let m = parse_toml("a = 1_000_000\nb = -42").unwrap();
        assert_eq!(m["a"].as_i64(), Some(1_000_000));
        assert_eq!(m["b"].as_i64(), Some(-42));
    }

    #[test]
    fn nested_arrays() {
        let m = parse_toml("a = [[1, 2], [3]]").unwrap();
        let outer = m["a"].as_array().unwrap();
        assert_eq!(outer.len(), 2);
        assert_eq!(outer[0].as_array().unwrap()[1].as_i64(), Some(2));
    }

    #[test]
    fn errors_carry_line_numbers() {
        let e = parse_toml("ok = 1\nbroken").unwrap_err();
        assert_eq!(e.line, 2);
        let e = parse_toml("[unclosed").unwrap_err();
        assert_eq!(e.line, 1);
    }

    #[test]
    fn duplicate_keys_rejected() {
        assert!(parse_toml("a = 1\na = 2").is_err());
    }

    #[test]
    fn quoted_table_segments_flatten_to_raw_names() {
        // BENCH_BUDGETS.toml quotes slash-bearing model ids; the flat key
        // must carry the raw name so lookups by model id succeed.
        let m = parse_toml("[serving.floors.\"lora-tiny/b1\"]\ndecode_tok_s = 100.0").unwrap();
        assert_eq!(m["serving.floors.lora-tiny/b1.decode_tok_s"].as_f64(), Some(100.0));
    }

    #[test]
    fn string_escapes() {
        let m = parse_toml(r#"s = "say \"hi\"""#).unwrap();
        assert_eq!(m["s"].as_str(), Some(r#"say "hi""#));
    }
}
