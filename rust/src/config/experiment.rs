//! Typed experiment configuration: what the launcher (`flora train ...`),
//! the examples and the bench harnesses all consume. Buildable from a TOML
//! file (`--config`), from CLI overrides, or programmatically (benches).

use std::collections::BTreeMap;

use super::toml::{parse_toml, TomlValue};
use crate::coordinator::method::MethodSpec;
use crate::opt::{CompressorKind, OptimizerKind, RankSchedule};
use crate::tensor::Parallelism;

/// Which synthetic workload drives training (DESIGN.md §4 mappings).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TaskKind {
    /// XSum-sim summarization (ROUGE)
    Sum,
    /// IWSLT-sim translation (BLEU)
    Mt,
    /// C4-sim language modelling (perplexity)
    Lm,
    /// CIFAR-sim image classification (accuracy)
    Vit,
}

impl TaskKind {
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "sum" => Ok(TaskKind::Sum),
            "mt" => Ok(TaskKind::Mt),
            "lm" => Ok(TaskKind::Lm),
            "vit" => Ok(TaskKind::Vit),
            _ => Err(format!("unknown task {s:?} (want sum|mt|lm|vit)")),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            TaskKind::Sum => "sum",
            TaskKind::Mt => "mt",
            TaskKind::Lm => "lm",
            TaskKind::Vit => "vit",
        }
    }

    /// The task a model name implies when the user gives none: `vit-*`
    /// models only ever train on the image task (the launcher applies
    /// this so `--model vit-tiny` works without an explicit `--task vit`).
    pub fn implied_by_model(model: &str) -> Option<TaskKind> {
        if model.starts_with("vit") {
            Some(TaskKind::Vit)
        } else {
            None
        }
    }
}

/// Core training hyper-parameters (shared by every experiment).
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub model: String,
    pub task: TaskKind,
    pub method: MethodSpec,
    pub optimizer: OptimizerKind,
    pub lr: f32,
    pub steps: usize,
    /// gradient-accumulation length τ (Algorithm 1); 1 disables
    pub tau: usize,
    /// momentum resample interval κ (Algorithm 2)
    pub kappa: usize,
    pub batch: usize,
    pub seed: u64,
    pub eval_every: usize,
    pub eval_samples: usize,
    /// tensor-kernel thread budget (`--parallelism N`);
    /// `Trainer::with_runtime` installs it process-wide, so it takes
    /// effect on every construction path. Bit-identical results at
    /// every setting — see `tensor::Parallelism`.
    pub parallelism: Parallelism,
    /// data-parallel worker count (`--workers N` / `train.workers`).
    /// Only the dp tier (`flora train-dp`, `runtime::dp`) consumes
    /// values above 1 — `flora train` rejects them loudly. Results are
    /// bit-identical at every setting; see `docs/DISTRIBUTED.md`.
    pub workers: usize,
    /// adaptive-rank schedule for the `adarank` compressor
    /// (`--rank-schedule` / `train.rank_schedule`): the momentum
    /// subspace shrinks at κ-resample boundaries. Ignored by the other
    /// compressors (they run at the fixed method rank).
    pub rank_schedule: RankSchedule,
}

impl TrainConfig {
    /// The single-process trainer's worker guard, shared by `flora
    /// train` and testable without a CLI round-trip (rust/tests/ops.rs
    /// pins the exact message): values above 1 belong to the dp tier.
    pub fn reject_multi_worker(&self) -> Result<(), String> {
        if self.workers > 1 {
            return Err(format!(
                "train is the single-process trainer; --workers {} is the \
                 data-parallel tier — use `flora train-dp` (docs/DISTRIBUTED.md)",
                self.workers
            ));
        }
        Ok(())
    }
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self {
            model: "lm-small".into(),
            task: TaskKind::Sum,
            method: MethodSpec::Flora { rank: 16 },
            optimizer: OptimizerKind::Adafactor,
            lr: 0.05,
            steps: 200,
            tau: 1,
            kappa: 1000,
            batch: 4,
            seed: 0,
            eval_every: 50,
            eval_samples: 16,
            parallelism: Parallelism::single(),
            workers: 1,
            rank_schedule: RankSchedule::Fixed,
        }
    }
}

/// A full experiment: training config + where artifacts live.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub train: TrainConfig,
    pub artifacts_dir: String,
    pub name: String,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        Self {
            train: TrainConfig::default(),
            artifacts_dir: "artifacts".into(),
            name: "experiment".into(),
        }
    }
}

impl ExperimentConfig {
    /// Load from a TOML file; unknown keys are an error (typo defence).
    pub fn from_toml_str(doc: &str) -> Result<Self, String> {
        let map = parse_toml(doc).map_err(|e| e.to_string())?;
        Self::from_map(&map)
    }

    pub fn from_file(path: &str) -> Result<Self, String> {
        let doc = std::fs::read_to_string(path)
            .map_err(|e| format!("reading {path}: {e}"))?;
        Self::from_toml_str(&doc)
    }

    pub(crate) fn from_map(map: &BTreeMap<String, TomlValue>) -> Result<Self, String> {
        let mut cfg = ExperimentConfig::default();
        let mut method_name: Option<String> = None;
        let mut rank: Option<u64> = None;
        let mut compressor: Option<CompressorKind> = None;
        for (k, v) in map {
            match k.as_str() {
                "name" => cfg.name = req_str(k, v)?,
                "artifacts_dir" => cfg.artifacts_dir = req_str(k, v)?,
                "train.model" => cfg.train.model = req_str(k, v)?,
                "train.task" => cfg.train.task = TaskKind::parse(&req_str(k, v)?)?,
                "train.method" => method_name = Some(req_str(k, v)?),
                "train.rank" => rank = Some(req_int(k, v)? as u64),
                "train.compressor" => {
                    compressor = Some(CompressorKind::parse(&req_str(k, v)?)?)
                }
                "train.rank_schedule" => {
                    cfg.train.rank_schedule = RankSchedule::parse(&req_str(k, v)?)?
                }
                "train.optimizer" => {
                    cfg.train.optimizer = OptimizerKind::parse(&req_str(k, v)?)?
                }
                "train.lr" => cfg.train.lr = req_f64(k, v)? as f32,
                "train.steps" => cfg.train.steps = req_int(k, v)? as usize,
                "train.tau" => cfg.train.tau = req_int(k, v)? as usize,
                "train.kappa" => cfg.train.kappa = req_int(k, v)? as usize,
                "train.batch" => cfg.train.batch = req_int(k, v)? as usize,
                "train.seed" => cfg.train.seed = req_int(k, v)? as u64,
                "train.eval_every" => cfg.train.eval_every = req_int(k, v)? as usize,
                "train.eval_samples" => cfg.train.eval_samples = req_int(k, v)? as usize,
                "train.parallelism" => {
                    let n = req_int(k, v)?;
                    if n < 1 {
                        return Err("parallelism must be >= 1".into());
                    }
                    cfg.train.parallelism = Parallelism::new(n as usize);
                }
                "train.workers" => {
                    let n = req_int(k, v)?;
                    if n < 1 {
                        return Err("workers must be >= 1".into());
                    }
                    cfg.train.workers = n as usize;
                }
                _ => return Err(format!("unknown config key {k:?}")),
            }
        }
        if let Some(name) = method_name {
            cfg.train.method = MethodSpec::parse(&name, rank.unwrap_or(16) as usize)?;
        }
        if let Some(kind) = compressor {
            cfg.train.method = cfg.train.method.with_compressor(kind)?;
        }
        if cfg.train.tau == 0 || cfg.train.batch == 0 {
            return Err("tau and batch must be >= 1".into());
        }
        check_pool_budget(&cfg.train)?;
        Ok(cfg)
    }
}

/// Loud pool-budget guard: the kernel pool is grow-only and process-wide,
/// so `workers × parallelism` (dp tasks times each task's band budget)
/// above [`crate::tensor::POOL_BUDGET`] would pin an absurd thread count
/// for the process lifetime. Every config entry point rejects it up
/// front with the arithmetic spelled out.
pub(crate) fn check_pool_budget(train: &TrainConfig) -> Result<(), String> {
    let total = train.workers * train.parallelism.threads();
    if total > crate::tensor::POOL_BUDGET {
        return Err(format!(
            "workers ({}) x parallelism ({}) = {} exceeds the pool budget of {} \
             threads — lower one of them",
            train.workers,
            train.parallelism.threads(),
            total,
            crate::tensor::POOL_BUDGET,
        ));
    }
    Ok(())
}

fn req_str(k: &str, v: &TomlValue) -> Result<String, String> {
    v.as_str()
        .map(|s| s.to_string())
        .ok_or_else(|| format!("{k}: expected string"))
}

fn req_int(k: &str, v: &TomlValue) -> Result<i64, String> {
    v.as_i64().ok_or_else(|| format!("{k}: expected integer"))
}

fn req_f64(k: &str, v: &TomlValue) -> Result<f64, String> {
    v.as_f64().ok_or_else(|| format!("{k}: expected number"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_sane() {
        let c = ExperimentConfig::default();
        assert_eq!(c.train.model, "lm-small");
        assert!(c.train.tau >= 1);
    }

    #[test]
    fn full_roundtrip_from_toml() {
        let doc = r#"
            name = "table1-flora8"
            artifacts_dir = "artifacts"
            [train]
            model = "lm-small"
            task = "mt"
            method = "flora"
            rank = 8
            optimizer = "adafactor"
            lr = 0.03
            steps = 300
            tau = 16
            kappa = 500
            batch = 4
            seed = 7
        "#;
        let c = ExperimentConfig::from_toml_str(doc).unwrap();
        assert_eq!(c.name, "table1-flora8");
        assert_eq!(c.train.task, TaskKind::Mt);
        assert_eq!(c.train.method, MethodSpec::Flora { rank: 8 });
        assert_eq!(c.train.optimizer, OptimizerKind::Adafactor);
        assert_eq!(c.train.tau, 16);
        assert_eq!(c.train.lr, 0.03);
    }

    #[test]
    fn compressor_and_rank_schedule_keys() {
        let doc = r#"
            [train]
            method = "flora"
            rank = 8
            compressor = "altlora"
        "#;
        let c = ExperimentConfig::from_toml_str(doc).unwrap();
        assert_eq!(c.train.method, MethodSpec::AltLora { rank: 8 });
        let doc = r#"
            [train]
            method = "flora"
            rank = 16
            compressor = "adarank"
            rank_schedule = "halve-at:3"
        "#;
        let c = ExperimentConfig::from_toml_str(doc).unwrap();
        assert_eq!(c.train.method, MethodSpec::AdaRank { rank: 16 });
        assert_eq!(c.train.rank_schedule, RankSchedule::HalveAt { every: 3 });
        // default schedule is fixed; bad values are loud
        assert_eq!(
            ExperimentConfig::default().train.rank_schedule,
            RankSchedule::Fixed
        );
        let e = ExperimentConfig::from_toml_str(r#"train.compressor = "svd""#)
            .unwrap_err();
        assert!(e.contains("unknown compressor"), "{e}");
        let e =
            ExperimentConfig::from_toml_str(r#"train.rank_schedule = "decay""#)
                .unwrap_err();
        assert!(e.contains("rank schedule"), "{e}");
        // compressor only re-routes the flora family
        let e = ExperimentConfig::from_toml_str(
            "train.method = \"galore\"\ntrain.compressor = \"adarank\"",
        )
        .unwrap_err();
        assert!(e.contains("compressor"), "{e}");
    }

    #[test]
    fn bad_optimizer_rejected() {
        let e = ExperimentConfig::from_toml_str(r#"train.optimizer = "adamw""#)
            .unwrap_err();
        assert!(e.contains("unknown optimizer"), "{e}");
    }

    #[test]
    fn unknown_key_rejected() {
        let e = ExperimentConfig::from_toml_str("train.stepz = 5").unwrap_err();
        assert!(e.contains("unknown config key"));
    }

    #[test]
    fn bad_task_rejected() {
        let e = ExperimentConfig::from_toml_str(r#"train.task = "xsum""#).unwrap_err();
        assert!(e.contains("unknown task"));
    }

    #[test]
    fn zero_tau_rejected() {
        assert!(ExperimentConfig::from_toml_str("train.tau = 0").is_err());
    }

    #[test]
    fn parallelism_parses_and_rejects_zero() {
        let c = ExperimentConfig::from_toml_str("train.parallelism = 4").unwrap();
        assert_eq!(c.train.parallelism, Parallelism::new(4));
        assert_eq!(
            ExperimentConfig::default().train.parallelism,
            Parallelism::single()
        );
        assert!(ExperimentConfig::from_toml_str("train.parallelism = 0").is_err());
    }

    #[test]
    fn workers_parse_reject_zero_and_guard_the_pool_budget() {
        let c = ExperimentConfig::from_toml_str("train.workers = 4").unwrap();
        assert_eq!(c.train.workers, 4);
        assert_eq!(ExperimentConfig::default().train.workers, 1);
        assert!(ExperimentConfig::from_toml_str("train.workers = 0").is_err());
        let e = ExperimentConfig::from_toml_str(
            "train.workers = 16\ntrain.parallelism = 16",
        )
        .unwrap_err();
        assert!(e.contains("pool budget"), "{e}");
        assert!(e.contains("256"), "spell out the arithmetic: {e}");
    }

    #[test]
    fn vit_models_imply_the_vit_task() {
        assert_eq!(TaskKind::implied_by_model("vit-tiny"), Some(TaskKind::Vit));
        assert_eq!(TaskKind::implied_by_model("vit-cifar"), Some(TaskKind::Vit));
        assert_eq!(TaskKind::implied_by_model("lora-tiny"), None);
        assert_eq!(TaskKind::implied_by_model("lm-small"), None);
    }

    #[test]
    fn task_kind_parse_all() {
        for (s, k) in [
            ("sum", TaskKind::Sum),
            ("mt", TaskKind::Mt),
            ("lm", TaskKind::Lm),
            ("vit", TaskKind::Vit),
        ] {
            assert_eq!(TaskKind::parse(s).unwrap(), k);
            assert_eq!(k.name(), s);
        }
    }
}
