//! Typed `flora train-dp` configuration: the shared training knobs of
//! [`TrainConfig`] (including `train.workers`) plus the dp-tier policy —
//! the logical shard count and the reduce wire format. Buildable from a
//! TOML file with `[train]`/`[dp]` tables, with CLI flags layered on top
//! by the launcher.

use std::collections::BTreeMap;

use super::experiment::{check_pool_budget, ExperimentConfig, TaskKind, TrainConfig};
use super::toml::{parse_toml, TomlValue};
use crate::coordinator::method::MethodSpec;
use crate::runtime::dp::ReduceMode;

/// Everything `flora train-dp` needs for one data-parallel run.
///
/// The **shard count is the mathematical grain** of a dp run: it fixes
/// the data partition and the fixed-order reduction slots. `workers`
/// only decides how many threads execute those shards, which is why the
/// trainer is bit-identical at every worker count (docs/DISTRIBUTED.md).
///
/// ```
/// use flora::config::DpConfig;
/// use flora::runtime::dp::ReduceMode;
///
/// let cfg = DpConfig::from_toml_str(r#"
///     [train]
///     model = "lora-tiny"
///     workers = 2
///     steps = 8
///     [dp]
///     shards = 4
///     reduce = "compressed"
/// "#).unwrap();
/// assert_eq!(cfg.train.workers, 2);
/// assert_eq!(cfg.shards, 4);
/// assert_eq!(cfg.reduce, ReduceMode::Compressed);
/// cfg.validate().unwrap();
/// // unknown keys are an error (typo defence)
/// assert!(DpConfig::from_toml_str("dp.shardz = 2").is_err());
/// // more workers than shards cannot be scheduled
/// let mut bad = cfg.clone();
/// bad.train.workers = 8;
/// assert!(bad.validate().unwrap_err().contains("workers"));
/// ```
#[derive(Clone, Debug)]
pub struct DpConfig {
    /// shared training knobs (model, optimizer, lr, τ, κ, seed,
    /// `workers`, `parallelism`, ...)
    pub train: TrainConfig,
    /// logical gradient shards per optimizer step — the determinism
    /// grain; per-step documents consumed = `shards × batch`
    pub shards: usize,
    /// what workers put on the wire (`compressed` = rank-r projected
    /// states, `full` = raw gradients; the A/B for the comms claim)
    pub reduce: ReduceMode,
}

impl Default for DpConfig {
    fn default() -> Self {
        Self {
            // dp trains the native LM family on the language task
            train: TrainConfig {
                model: "lora-tiny".into(),
                task: TaskKind::Lm,
                method: MethodSpec::Flora { rank: 8 },
                steps: 20,
                batch: 2,
                kappa: 4,
                ..TrainConfig::default()
            },
            shards: 4,
            reduce: ReduceMode::Compressed,
        }
    }
}

impl DpConfig {
    /// Load from a TOML document; unknown keys are an error.
    pub fn from_toml_str(doc: &str) -> Result<Self, String> {
        let map = parse_toml(doc).map_err(|e| e.to_string())?;
        Self::from_map(&map)
    }

    pub fn from_file(path: &str) -> Result<Self, String> {
        let doc = std::fs::read_to_string(path)
            .map_err(|e| format!("reading {path}: {e}"))?;
        Self::from_toml_str(&doc)
    }

    fn from_map(map: &BTreeMap<String, TomlValue>) -> Result<Self, String> {
        let mut cfg = DpConfig::default();
        // split off the dp.* keys, hand the rest to the shared
        // experiment parser (which owns the train.* vocabulary)
        let mut rest: BTreeMap<String, TomlValue> = BTreeMap::new();
        for (k, v) in map {
            match k.as_str() {
                "dp.shards" => {
                    let n = v.as_i64().ok_or_else(|| format!("{k}: expected integer"))?;
                    if n < 1 {
                        return Err(format!("{k}: must be >= 1"));
                    }
                    cfg.shards = n as usize;
                }
                "dp.reduce" => {
                    let s = v
                        .as_str()
                        .ok_or_else(|| format!("{k}: expected string"))?;
                    cfg.reduce = ReduceMode::parse(s)?;
                }
                _ => {
                    rest.insert(k.clone(), v.clone());
                }
            }
        }
        if !rest.is_empty() {
            // a bare `train.rank` means "flora at this rank" here (dp is
            // always flora); the experiment parser would drop it without
            // an accompanying method key
            if rest.contains_key("train.rank") && !rest.contains_key("train.method") {
                rest.insert("train.method".into(), TomlValue::Str("flora".into()));
            }
            let exp = ExperimentConfig::from_map(&rest)?;
            // the experiment parser starts from ITS defaults; keep only
            // train.* (dp has no artifacts), re-seating dp's model/task
            // defaults for keys the document left unset
            let mut train = exp.train;
            if !rest.contains_key("train.model") {
                train.model = cfg.train.model.clone();
            }
            if !rest.contains_key("train.task") {
                train.task = cfg.train.task;
            }
            if !rest.contains_key("train.method") {
                train.method = cfg.train.method;
            }
            if !rest.contains_key("train.steps") {
                train.steps = cfg.train.steps;
            }
            if !rest.contains_key("train.batch") {
                train.batch = cfg.train.batch;
            }
            if !rest.contains_key("train.kappa") {
                train.kappa = cfg.train.kappa;
            }
            cfg.train = train;
        }
        Ok(cfg)
    }

    /// All the cross-field rules, with loud errors: the dp tier needs a
    /// Flora method on the LM task, at least as many shards as workers,
    /// and a `workers × parallelism` product within the pool budget.
    pub fn validate(&self) -> Result<(), String> {
        let t = &self.train;
        // the adaptive-rank grid is single-process only: the dp reduce
        // sums fixed-shape [n, r] sketches across workers, and neither
        // AltLoRA's dual sketch nor AdaRank's shrinking subspace has a
        // wire format yet. Reject them by name, ahead of the generic
        // non-Flora arm, so the hint points at the right tier.
        if matches!(
            t.method,
            MethodSpec::AltLora { .. } | MethodSpec::AdaRank { .. }
        ) {
            return Err(format!(
                "train-dp exchanges Flora-compressed gradients; compressor {} is \
                 single-process only (rust/src/opt/{}.rs) — drop --compressor or \
                 use `flora train`",
                compressor_tag(&t.method),
                compressor_file(&t.method),
            ));
        }
        if !matches!(t.method, MethodSpec::Flora { .. }) {
            return Err(format!(
                "train-dp exchanges Flora-compressed gradients; method {:?} has no \
                 compressed wire format (use --method flora --rank R)",
                t.method
            ));
        }
        if t.task != TaskKind::Lm {
            return Err(format!(
                "train-dp shards the C4-sim LM corpus; task {:?} is not sharded \
                 (use the lora-* models / lm task)",
                t.task
            ));
        }
        if self.shards < 1 {
            return Err("dp.shards must be >= 1".into());
        }
        if t.workers > self.shards {
            return Err(format!(
                "workers ({}) exceeds shards ({}) — extra workers would idle; \
                 lower --workers or raise --shards",
                t.workers, self.shards
            ));
        }
        if t.steps < 1 || t.batch < 1 || t.tau < 1 || t.kappa < 1 {
            return Err("steps, batch, tau and kappa must all be >= 1".into());
        }
        check_pool_budget(t)
    }

    /// The Flora rank of the configured method (call after `validate`).
    pub fn rank(&self) -> usize {
        match self.train.method {
            MethodSpec::Flora { rank } => rank,
            _ => 0,
        }
    }
}

fn compressor_tag(m: &MethodSpec) -> &'static str {
    match m {
        MethodSpec::AltLora { .. } => "altlora",
        MethodSpec::AdaRank { .. } => "adarank",
        _ => "flora",
    }
}

fn compressor_file(m: &MethodSpec) -> &'static str {
    match m {
        MethodSpec::AltLora { .. } => "altlora",
        MethodSpec::AdaRank { .. } => "schedule",
        _ => "flora",
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Parallelism;

    #[test]
    fn defaults_validate() {
        let c = DpConfig::default();
        c.validate().unwrap();
        assert_eq!(c.shards, 4);
        assert_eq!(c.reduce, ReduceMode::Compressed);
        assert_eq!(c.rank(), 8);
    }

    #[test]
    fn dp_keys_and_train_keys_coexist() {
        let c = DpConfig::from_toml_str(
            r#"
            [train]
            model = "lora-small"
            optimizer = "sgd"
            workers = 3
            steps = 6
            [dp]
            shards = 6
            reduce = "full"
            "#,
        )
        .unwrap();
        assert_eq!(c.train.model, "lora-small");
        assert_eq!(c.train.workers, 3);
        assert_eq!(c.shards, 6);
        assert_eq!(c.reduce, ReduceMode::Full);
        c.validate().unwrap();
    }

    #[test]
    fn rejects_non_flora_and_non_lm() {
        let mut c = DpConfig::default();
        c.train.method = MethodSpec::Naive;
        assert!(c.validate().unwrap_err().contains("wire format"));
        let mut c = DpConfig::default();
        c.train.task = TaskKind::Sum;
        assert!(c.validate().unwrap_err().contains("LM"));
    }

    #[test]
    fn rejects_the_single_process_compressor_grid_by_name() {
        let mut c = DpConfig::default();
        c.train.method = MethodSpec::AltLora { rank: 8 };
        let e = c.validate().unwrap_err();
        assert!(e.contains("compressor altlora is single-process only"), "{e}");
        assert!(e.contains("rust/src/opt/altlora.rs"), "{e}");
        c.train.method = MethodSpec::AdaRank { rank: 8 };
        let e = c.validate().unwrap_err();
        assert!(e.contains("compressor adarank is single-process only"), "{e}");
        assert!(e.contains("rust/src/opt/schedule.rs"), "{e}");
        assert!(e.contains("flora train"), "{e}");
    }

    #[test]
    fn pool_budget_guard_is_loud() {
        let mut c = DpConfig::default();
        c.train.workers = 32;
        c.train.parallelism = Parallelism::new(8);
        c.shards = 32;
        let e = c.validate().unwrap_err();
        assert!(e.contains("pool budget"), "{e}");
    }

    #[test]
    fn bad_reduce_mode_rejected() {
        let e = DpConfig::from_toml_str(r#"dp.reduce = "zstd""#).unwrap_err();
        assert!(e.contains("compressed|full"), "{e}");
    }
}
