//! Typed `flora serve` configuration: batching knobs, model choice and
//! the synthetic-traffic parameters, buildable from a `[serve]` TOML
//! table (`--config`) with CLI flags layered on top by the launcher.

use std::collections::BTreeMap;

use super::toml::{parse_toml, TomlValue};
use crate::tensor::Parallelism;

/// Everything `flora serve` needs to run one serving session.
///
/// ```
/// use flora::config::ServeConfig;
///
/// let cfg = ServeConfig::from_toml_str(r#"
///     [serve]
///     model = "lora-small"
///     max_batch = 8
///     max_wait_ms = 25
///     adapters = 4
///     rank = 8
/// "#).unwrap();
/// assert_eq!(cfg.model, "lora-small");
/// assert_eq!(cfg.max_batch, 8);
/// assert_eq!(cfg.max_wait_ms, 25);
/// assert_eq!(cfg.rank, 8);
/// // unknown keys are an error (typo defence)
/// assert!(ServeConfig::from_toml_str("serve.max_batsh = 2").is_err());
/// ```
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// native catalog LM size (`lora-tiny` | `lora-small` | `lora-base`)
    pub model: String,
    /// close a batch at this many shape-compatible requests
    pub max_batch: usize,
    /// ... or once the oldest queued request has waited this long
    pub max_wait_ms: u64,
    /// synthetic adapters to register (`adapter-0` … `adapter-{n-1}`)
    pub adapters: usize,
    /// adapter registry capacity (defaults to `adapters`, min 1)
    pub capacity: usize,
    /// LoRA rank of the synthetic adapters
    pub rank: usize,
    /// synthetic requests to submit
    pub requests: usize,
    /// prompt length per request; 0 means half the model's seq_len
    pub prompt_len: usize,
    /// tokens to generate per request; 0 means a quarter of seq_len
    pub max_new: usize,
    /// base-weight + synthetic-adapter seed
    pub seed: u64,
    /// synthetic arrival gap between consecutive requests
    pub gap_ms: u64,
    /// tensor-kernel thread budget (installed process-wide)
    pub parallelism: Parallelism,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            model: "lora-tiny".into(),
            max_batch: 4,
            max_wait_ms: 50,
            adapters: 3,
            capacity: 0,
            rank: 8,
            requests: 6,
            prompt_len: 0,
            max_new: 0,
            seed: 0,
            gap_ms: 0,
            parallelism: Parallelism::single(),
        }
    }
}

impl ServeConfig {
    /// Load from a TOML document; unknown keys are an error.
    pub fn from_toml_str(doc: &str) -> Result<Self, String> {
        let map = parse_toml(doc).map_err(|e| e.to_string())?;
        Self::from_map(&map)
    }

    pub fn from_file(path: &str) -> Result<Self, String> {
        let doc = std::fs::read_to_string(path)
            .map_err(|e| format!("reading {path}: {e}"))?;
        Self::from_toml_str(&doc)
    }

    fn from_map(map: &BTreeMap<String, TomlValue>) -> Result<Self, String> {
        let mut cfg = ServeConfig::default();
        for (k, v) in map {
            match k.as_str() {
                "serve.model" => cfg.model = req_str(k, v)?,
                "serve.max_batch" => cfg.max_batch = req_pos(k, v)?,
                "serve.max_wait_ms" => cfg.max_wait_ms = req_int(k, v)? as u64,
                "serve.adapters" => cfg.adapters = req_pos(k, v)?,
                "serve.capacity" => cfg.capacity = req_int(k, v)? as usize,
                "serve.rank" => cfg.rank = req_pos(k, v)?,
                "serve.requests" => cfg.requests = req_pos(k, v)?,
                "serve.prompt_len" => cfg.prompt_len = req_int(k, v)? as usize,
                "serve.max_new" => cfg.max_new = req_int(k, v)? as usize,
                "serve.seed" => cfg.seed = req_int(k, v)? as u64,
                "serve.gap_ms" => cfg.gap_ms = req_int(k, v)? as u64,
                "serve.parallelism" => {
                    cfg.parallelism = Parallelism::new(req_pos(k, v)?);
                }
                _ => return Err(format!("unknown config key {k:?}")),
            }
        }
        Ok(cfg)
    }

    /// Registry capacity after defaulting: `capacity` if set, else room
    /// for every configured adapter.
    pub fn effective_capacity(&self) -> usize {
        if self.capacity > 0 {
            self.capacity
        } else {
            self.adapters.max(1)
        }
    }

    /// Prompt length after defaulting against a model's `seq_len`.
    pub fn effective_prompt_len(&self, seq_len: usize) -> usize {
        if self.prompt_len > 0 {
            self.prompt_len
        } else {
            (seq_len / 2).max(1)
        }
    }

    /// Generation length after defaulting against a model's `seq_len`.
    pub fn effective_max_new(&self, seq_len: usize) -> usize {
        if self.max_new > 0 {
            self.max_new
        } else {
            (seq_len / 4).max(1)
        }
    }
}

fn req_str(k: &str, v: &TomlValue) -> Result<String, String> {
    v.as_str()
        .map(|s| s.to_string())
        .ok_or_else(|| format!("{k}: expected string"))
}

fn req_int(k: &str, v: &TomlValue) -> Result<i64, String> {
    let n = v.as_i64().ok_or_else(|| format!("{k}: expected integer"))?;
    if n < 0 {
        return Err(format!("{k}: must be >= 0"));
    }
    Ok(n)
}

fn req_pos(k: &str, v: &TomlValue) -> Result<usize, String> {
    let n = req_int(k, v)?;
    if n < 1 {
        return Err(format!("{k}: must be >= 1"));
    }
    Ok(n as usize)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_and_effective_values() {
        let c = ServeConfig::default();
        assert_eq!(c.model, "lora-tiny");
        assert_eq!(c.effective_capacity(), 3);
        assert_eq!(c.effective_prompt_len(16), 8);
        assert_eq!(c.effective_max_new(16), 4);
    }

    #[test]
    fn full_roundtrip_from_toml() {
        let c = ServeConfig::from_toml_str(
            r#"
            [serve]
            model = "lora-base"
            max_batch = 8
            max_wait_ms = 10
            adapters = 5
            capacity = 2
            rank = 16
            requests = 20
            prompt_len = 12
            max_new = 6
            seed = 9
            gap_ms = 3
            parallelism = 2
            "#,
        )
        .unwrap();
        assert_eq!(c.model, "lora-base");
        assert_eq!((c.max_batch, c.max_wait_ms), (8, 10));
        assert_eq!((c.adapters, c.effective_capacity()), (5, 2));
        assert_eq!((c.rank, c.requests), (16, 20));
        assert_eq!((c.prompt_len, c.max_new), (12, 6));
        assert_eq!((c.seed, c.gap_ms), (9, 3));
        assert_eq!(c.parallelism, Parallelism::new(2));
    }

    #[test]
    fn rejects_unknown_and_invalid() {
        assert!(ServeConfig::from_toml_str("serve.modell = \"x\"").is_err());
        assert!(ServeConfig::from_toml_str("serve.max_batch = 0").is_err());
        assert!(ServeConfig::from_toml_str("serve.rank = -2").is_err());
        assert!(ServeConfig::from_toml_str("serve.model = 5").is_err());
    }
}
