//! Configuration system: a TOML-subset parser (no serde/toml crates in the
//! offline vendor set) plus the typed experiment configs the launcher and
//! benches consume.

pub mod dp;
pub mod experiment;
pub mod serve;
pub mod toml;

pub use dp::DpConfig;
pub use experiment::{ExperimentConfig, TaskKind, TrainConfig};
pub use serve::ServeConfig;
pub use toml::{parse_toml, TomlValue};
