//! Deterministic corpus sharding: the logical shard grid of a dp run.
//!
//! The grid is **fixed by config, never by worker count**. `shards`
//! defines both the data partition (which documents feed which gradient
//! shard — see `LmTask::fill_shard_batch`) and the reduction slots the
//! reducer sums in ascending order. Workers only claim shards
//! round-robin, so changing `--workers` changes *which thread* computes
//! a shard, never *what* is computed or *in what order* it is reduced —
//! the first half of the tier's W-invariance proof (docs/DISTRIBUTED.md).

use crate::data::corpus::LmTask;
use crate::data::LmBatch;

/// The shard grid of one dp run: `shards` gradient shards per data
/// step, each a `batch`-row block of the deterministic document stream.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardPlan {
    pub shards: usize,
    /// rows per shard batch (the per-shard micro-batch size)
    pub batch: usize,
}

impl ShardPlan {
    pub fn new(shards: usize, batch: usize) -> Self {
        assert!(shards >= 1, "a dp run needs at least one shard");
        assert!(batch >= 1, "a shard batch needs at least one row");
        Self { shards, batch }
    }

    /// First document index of `(step, shard)`: contiguous blocks in
    /// shard order, so concatenating the shards of consecutive steps
    /// reproduces the serial stream exactly (regression-tested in
    /// `data::corpus`).
    pub fn start_cursor(&self, step: u64, shard: usize) -> u64 {
        debug_assert!(shard < self.shards);
        (step * self.shards as u64 + shard as u64) * self.batch as u64
    }

    /// Shards owned by worker `w` of `workers`: round-robin `w, w+W,
    /// w+2W, …` — every shard lands on exactly one worker for any
    /// `workers >= 1`, and `workers = shards` gives one shard each.
    pub fn assignment(&self, workers: usize, w: usize) -> Vec<usize> {
        (w..self.shards).step_by(workers.max(1)).collect()
    }

    /// Fill `out` with shard `shard`'s rows of data step `step`.
    pub fn fill(&self, task: &LmTask, out: &mut LmBatch, split: u64, step: u64, shard: usize) {
        debug_assert_eq!(out.batch, self.batch);
        task.fill_shard_batch(out, split, step, shard, self.shards);
    }

    /// Documents one data step consumes across all shards.
    pub fn docs_per_step(&self) -> u64 {
        (self.shards * self.batch) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn assignment_partitions_all_shards() {
        let plan = ShardPlan::new(7, 2);
        for workers in 1..=7 {
            let mut seen = vec![0usize; plan.shards];
            for w in 0..workers {
                for s in plan.assignment(workers, w) {
                    seen[s] += 1;
                }
            }
            assert!(seen.iter().all(|&c| c == 1), "workers={workers}: {seen:?}");
        }
    }

    #[test]
    fn start_cursor_is_contiguous_in_shard_order() {
        let plan = ShardPlan::new(4, 3);
        let mut want = 0u64;
        for step in 0..3u64 {
            for shard in 0..plan.shards {
                assert_eq!(plan.start_cursor(step, shard), want);
                want += plan.batch as u64;
            }
        }
        assert_eq!(plan.docs_per_step(), 12);
    }

    #[test]
    fn fill_agrees_with_corpus_shard_addressing() {
        // ShardPlan::fill and LmTask::fill_shard_batch must share one
        // cursor formula — cross-layer consistency check
        let t = LmTask::new(128, 16, 3);
        let plan = ShardPlan::new(4, 2);
        let mut a = LmBatch::zeros(2, 16);
        let mut b = LmBatch::zeros(2, 16);
        plan.fill(&t, &mut a, 0, 5, 3);
        let mut cursor = plan.start_cursor(5, 3);
        t.fill_batch(&mut b, 0, &mut cursor);
        assert_eq!(a.tokens, b.tokens);
    }
}
