//! The fixed-order compressed all-reduce and its byte ledger.
//!
//! One dp data step produces `shards` payloads (per-parameter compressed
//! states, or raw gradients in `full` mode). [`reduce_fixed_order`] sums
//! them **in ascending shard order, on the calling thread**, via
//! `Matrix::reduce_sum` — every element accumulates shard contributions
//! left-to-right with a single f32 accumulator, so the reduced value is
//! bit-identical no matter how many workers produced the payloads or how
//! the kernel pool banded the rows. This is the second half of the
//! tier's W-invariance proof (docs/DISTRIBUTED.md).
//!
//! [`CommsLedger`] does the paper's accounting: what crossed the
//! reduction boundary (`bytes_sent`) vs what full-gradient exchange
//! would have moved (`bytes_full`). Both are exact integer counts, so
//! the compression ratio is testable with `==`, not tolerance.

use std::collections::BTreeMap;

use crate::model::is_projectable;
use crate::tensor::Matrix;

/// What workers put on the wire.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReduceMode {
    /// rank-r projected states for projectable params (`C = G Aᵀ`,
    /// `n×r` floats instead of `n×m`) — the paper's thesis as a comms
    /// strategy
    Compressed,
    /// raw gradients — the A/B baseline the ledger compares against
    Full,
}

impl ReduceMode {
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "compressed" => Ok(ReduceMode::Compressed),
            "full" => Ok(ReduceMode::Full),
            _ => Err(format!("unknown reduce mode {s:?} (want compressed|full)")),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            ReduceMode::Compressed => "compressed",
            ReduceMode::Full => "full",
        }
    }
}

impl std::fmt::Display for ReduceMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Exact byte accounting of the gradient exchange, accumulated per data
/// step. "Sent" counts every shard's upload into the reduction (the
/// all-reduce ingress — the quantity the rank knob shrinks); "full" is
/// the same step under [`ReduceMode::Full`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CommsLedger {
    pub steps: u64,
    pub bytes_sent: u64,
    pub bytes_full: u64,
}

impl CommsLedger {
    pub fn record_step(&mut self, sent: u64, full: u64) {
        self.steps += 1;
        self.bytes_sent += sent;
        self.bytes_full += full;
    }

    /// bytes_sent / bytes_full — the measured compression ratio; 1.0
    /// for a `full`-mode run, ~`r/d` for compressed at square shapes.
    pub fn ratio(&self) -> f64 {
        if self.bytes_full == 0 {
            1.0
        } else {
            self.bytes_sent as f64 / self.bytes_full as f64
        }
    }

    pub fn per_step_sent(&self) -> u64 {
        if self.steps == 0 {
            0
        } else {
            self.bytes_sent / self.steps
        }
    }

    pub fn per_step_full(&self) -> u64 {
        if self.steps == 0 {
            0
        } else {
            self.bytes_full / self.steps
        }
    }
}

/// Analytic upload volume of ONE data step: `shards × Σ_p payload(p)`
/// bytes, where a projectable `n×m` parameter ships `n×r` f32s under
/// [`ReduceMode::Compressed`] and `n×m` otherwise (non-projectables —
/// embeddings, LN scales — always go full-size, exactly as Algorithm 1
/// keeps them uncompressed). The trainer's ledger and the
/// `BENCH_dp.json` mirror both derive from this one formula, so the
/// measured-vs-analytic check in the tests is exact.
pub fn step_bytes(
    shapes: &[(String, [usize; 2])],
    rank: usize,
    shards: usize,
    mode: ReduceMode,
) -> u64 {
    let per_shard: u64 = shapes
        .iter()
        .map(|(name, [n, m])| {
            let floats = if mode == ReduceMode::Compressed && is_projectable(name) {
                n * rank
            } else {
                n * m
            };
            4 * floats as u64
        })
        .sum();
    per_shard * shards as u64
}

/// Sum the per-shard payloads in **fixed ascending shard order**. All
/// payloads must carry identical key sets (the workers build them from
/// the same complete gradient `ParamSet`). Runs on the calling thread;
/// the inner elementwise sums may band across the pool without
/// affecting any element's summation order (`Matrix::reduce_sum`).
pub fn reduce_fixed_order(payloads: &[BTreeMap<String, Matrix>]) -> BTreeMap<String, Matrix> {
    assert!(!payloads.is_empty(), "reduce of zero shards");
    let mut out = BTreeMap::new();
    for name in payloads[0].keys() {
        let srcs: Vec<&Matrix> = payloads
            .iter()
            .map(|p| p.get(name).expect("shard payloads must share keys"))
            .collect();
        out.insert(name.clone(), Matrix::reduce_sum(&srcs));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_display_roundtrip() {
        assert_eq!(ReduceMode::parse("compressed").unwrap(), ReduceMode::Compressed);
        assert_eq!(ReduceMode::parse("full").unwrap(), ReduceMode::Full);
        assert_eq!(ReduceMode::Compressed.to_string(), "compressed");
        assert!(ReduceMode::parse("gzip").unwrap_err().contains("compressed|full"));
    }

    #[test]
    fn ledger_arithmetic_is_exact() {
        let mut l = CommsLedger::default();
        l.record_step(100, 400);
        l.record_step(100, 400);
        assert_eq!(l.steps, 2);
        assert_eq!(l.per_step_sent(), 100);
        assert_eq!(l.per_step_full(), 400);
        assert_eq!(l.ratio(), 0.25);
        assert_eq!(CommsLedger::default().ratio(), 1.0);
    }

    #[test]
    fn step_bytes_compresses_only_projectables() {
        let shapes = vec![
            ("embed/tok".to_string(), [64usize, 32usize]),
            ("layer0/attn/wq".to_string(), [32, 32]),
        ];
        let rank = 8;
        let full = step_bytes(&shapes, rank, 2, ReduceMode::Full);
        let comp = step_bytes(&shapes, rank, 2, ReduceMode::Compressed);
        // full: 2 shards * 4B * (64*32 + 32*32); compressed swaps the
        // attn matrix for 32*8
        assert_eq!(full, 2 * 4 * (64 * 32 + 32 * 32));
        assert_eq!(comp, 2 * 4 * (64 * 32 + 32 * 8));
    }

    #[test]
    fn reduce_fixed_order_is_left_to_right_per_element() {
        let mk = |v: f32| {
            let mut m = BTreeMap::new();
            m.insert("w".to_string(), Matrix::from_vec(1, 2, vec![v, v * 2.0]));
            m
        };
        let reduced = reduce_fixed_order(&[mk(1.0), mk(10.0), mk(100.0)]);
        // oracle: explicit serial left-to-right sum
        let mut oracle = Matrix::zeros(1, 2);
        for v in [1.0f32, 10.0, 100.0] {
            oracle.add_scaled_inplace(&Matrix::from_vec(1, 2, vec![v, v * 2.0]), 1.0);
        }
        let got: Vec<u32> = reduced["w"].data.iter().map(|x| x.to_bits()).collect();
        let want: Vec<u32> = oracle.data.iter().map(|x| x.to_bits()).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn reduce_preserves_nan_and_inf() {
        let mk = |v: f32| {
            let mut m = BTreeMap::new();
            m.insert("w".to_string(), Matrix::from_vec(1, 2, vec![v, 1.0]));
            m
        };
        let reduced = reduce_fixed_order(&[mk(f32::NAN), mk(2.0)]);
        assert!(reduced["w"].data[0].is_nan(), "NaN must survive the reduce");
        assert_eq!(reduced["w"].data[1], 3.0);
        let reduced = reduce_fixed_order(&[mk(f32::INFINITY), mk(2.0)]);
        assert!(reduced["w"].data[0].is_infinite());
    }
}
