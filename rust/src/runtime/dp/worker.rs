//! The in-process worker tier: shard gradients computed (and, in
//! compressed mode, rank-r projected) concurrently on the persistent
//! kernel pool.
//!
//! A worker is not a stateful object — it is a *task index* handed to
//! `tensor::pool_tasks`, which walks its round-robin shard assignment
//! and deposits each shard's result in that **shard's** slot. Nothing a
//! worker computes depends on which thread ran it: the shard batch is a
//! pure function of `(step, shard)`, the forward/backward kernels are
//! bit-identical at every thread budget, and the projection is
//! regenerated from the per-parameter seed. The reducer then walks the
//! slots in ascending shard order — so the whole step is a deterministic
//! function of the config, independent of `workers`.

use std::collections::BTreeMap;
use std::sync::Mutex;

use super::reduce::ReduceMode;
use super::shard::ShardPlan;
use crate::data::corpus::LmTask;
use crate::data::LmBatch;
use crate::model::{is_projectable, ParamSet, TransformerConfig};
use crate::rp;
use crate::tensor::{pool_tasks, Matrix};

/// Projection spec of one data step: the Flora rank plus the ACTIVE
/// cycle/subspace seed (Algorithm-1 cycle seed, or Algorithm-2 active
/// seed per `SubspaceTick::active_seed`). Per-parameter seeds derive
/// from it by enumeration index over the sorted `ParamSet`, exactly as
/// the single-process runtime does.
#[derive(Clone, Copy, Debug)]
pub struct StepProjection {
    pub rank: usize,
    pub cycle_seed: u64,
}

/// One shard's contribution to a step: its masked-mean loss and its
/// wire payload (compressed states for projectable params under
/// [`ReduceMode::Compressed`], raw gradients otherwise).
#[derive(Clone, Debug)]
pub struct ShardGrad {
    pub loss: f32,
    pub payload: BTreeMap<String, Matrix>,
}

/// Compute ONE shard's gradient payload for data step `step`.
pub fn shard_grad(
    model: &TransformerConfig,
    params: &ParamSet,
    task: &LmTask,
    plan: &ShardPlan,
    split: u64,
    step: u64,
    shard: usize,
    mode: ReduceMode,
    proj: StepProjection,
) -> Result<ShardGrad, String> {
    let mut batch = LmBatch::zeros(plan.batch, model.seq_len);
    plan.fill(task, &mut batch, split, step, shard);
    let (loss, grads) = model.loss_and_grad(
        params,
        &batch.tokens,
        &batch.mask,
        plan.batch,
        model.seq_len,
        true,
    )?;
    let payload = match mode {
        ReduceMode::Compressed => grads
            .iter()
            .enumerate()
            .map(|(idx, (name, g))| {
                if is_projectable(name) {
                    let seed = rp::param_seed(proj.cycle_seed, idx);
                    let a = rp::projection(seed, proj.rank, g.cols);
                    (name.clone(), rp::compress(g, &a))
                } else {
                    (name.clone(), g.clone())
                }
            })
            .collect(),
        ReduceMode::Full => grads,
    };
    Ok(ShardGrad { loss, payload })
}

/// Run every shard of one data step across `workers` pool tasks and
/// return the results **indexed by shard** — slot `s` holds shard `s`
/// no matter which worker computed it. Errors from any shard surface
/// (lowest shard index wins, deterministically).
#[allow(clippy::too_many_arguments)]
pub fn run_step_workers(
    model: &TransformerConfig,
    params: &ParamSet,
    task: &LmTask,
    plan: &ShardPlan,
    workers: usize,
    split: u64,
    step: u64,
    mode: ReduceMode,
    proj: StepProjection,
) -> Result<Vec<ShardGrad>, String> {
    let slots: Vec<Mutex<Option<Result<ShardGrad, String>>>> =
        (0..plan.shards).map(|_| Mutex::new(None)).collect();
    let workers = workers.clamp(1, plan.shards);
    pool_tasks(workers, |w| {
        for shard in plan.assignment(workers, w) {
            let r = shard_grad(model, params, task, plan, split, step, shard, mode, proj);
            *slots[shard].lock().unwrap_or_else(|p| p.into_inner()) = Some(r);
        }
    });
    let mut out = Vec::with_capacity(plan.shards);
    for (shard, slot) in slots.into_iter().enumerate() {
        let r = slot
            .into_inner()
            .unwrap_or_else(|p| p.into_inner())
            .unwrap_or_else(|| Err(format!("shard {shard} produced no result")));
        out.push(r?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn worker_counts_are_invisible_in_the_results() {
        // the unit-level half of the tier's bit-identity claim: the same
        // step computed by 1, 2, and 4 workers yields byte-identical
        // shard slots
        let model = TransformerConfig::tiny();
        let params = model.init(0);
        let task = LmTask::new(model.vocab, model.seq_len, 7);
        let plan = ShardPlan::new(4, 2);
        let proj = StepProjection { rank: 4, cycle_seed: 99 };
        let run = |workers: usize| {
            run_step_workers(
                &model,
                &params,
                &task,
                &plan,
                workers,
                0,
                0,
                ReduceMode::Compressed,
                proj,
            )
            .unwrap()
        };
        let base = run(1);
        for workers in [2usize, 4] {
            let got = run(workers);
            assert_eq!(got.len(), base.len());
            for (s, (a, b)) in base.iter().zip(&got).enumerate() {
                assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "shard {s} loss");
                for (name, ma) in &a.payload {
                    let mb = &b.payload[name];
                    let ba: Vec<u32> = ma.data.iter().map(|x| x.to_bits()).collect();
                    let bb: Vec<u32> = mb.data.iter().map(|x| x.to_bits()).collect();
                    assert_eq!(ba, bb, "workers={workers} shard {s} {name}");
                }
            }
        }
    }

    #[test]
    fn compressed_payloads_project_only_projectables() {
        let model = TransformerConfig::tiny();
        let params = model.init(0);
        let task = LmTask::new(model.vocab, model.seq_len, 7);
        let plan = ShardPlan::new(2, 2);
        let proj = StepProjection { rank: 4, cycle_seed: 5 };
        let g = shard_grad(
            &model,
            &params,
            &task,
            &plan,
            0,
            0,
            0,
            ReduceMode::Compressed,
            proj,
        )
        .unwrap();
        for (name, m) in &g.payload {
            let full = &params[name];
            if is_projectable(name) {
                assert_eq!((m.rows, m.cols), (full.rows, proj.rank), "{name}");
            } else {
                assert_eq!((m.rows, m.cols), (full.rows, full.cols), "{name}");
            }
        }
    }
}
