//! Data-parallel training with Flora-compressed communication — the
//! paper's thesis (*low-rank adapters are secretly gradient
//! compressors*) applied to the wire: workers exchange rank-r projected
//! gradients instead of full `n×m` grads, and the reducer decompresses
//! **once**, after summation, through the shared seeded projection.
//!
//! # Why `W=1` and `W=N` are bit-identical
//!
//! The whole tier is arranged so the optimizer-visible computation never
//! mentions the worker count:
//!
//! 1. **Data**: the corpus is addressed by a `(step, shard)` grid fixed
//!    by `dp.shards` — shard `s` of step `k` is documents
//!    `(k·S + s)·batch ..`, a pure function with no worker in it
//!    (`ShardPlan`, `LmTask::fill_shard_batch`).
//! 2. **Per-shard compute**: each shard's loss/gradient/compression is a
//!    deterministic function of `(params, step, shard)` — the kernels
//!    are bit-identical at every thread budget (the PR-4/5 invariant),
//!    and the projection is regenerated from the per-parameter seed.
//!    Workers only decide *which thread* evaluates the function.
//! 3. **Reduction**: shard payloads are summed in ascending shard order
//!    on the coordinating thread (`reduce_fixed_order`), every element
//!    left-to-right with one f32 accumulator — so the reduced gradient,
//!    and therefore the optimizer step, is byte-for-byte the same at
//!    every `--workers`. `flora train-dp --verify` re-runs at `W=1` and
//!    raw-bits-compares; the integration grid does `W ∈ {1,2,4}`.
//!
//! Compressed-mode reduction is *exact* (not approximate) relative to
//! compressing the summed gradient, by linearity: `Σ_s G_s Aᵀ =
//! (Σ_s G_s) Aᵀ`. The `full` reduce mode exists as the A/B baseline —
//! same trajectory up to float reassociation, ~`d/r`× the bytes
//! ([`CommsLedger`] measures; `docs/DISTRIBUTED.md` has the math).

pub mod reduce;
pub mod shard;
pub mod worker;

pub use reduce::{reduce_fixed_order, step_bytes, CommsLedger, ReduceMode};
pub use shard::ShardPlan;
pub use worker::{run_step_workers, shard_grad, ShardGrad, StepProjection};

use std::collections::BTreeMap;

use crate::config::DpConfig;
use crate::coordinator::seeds::{AccumSeeds, MomentumSeeds};
use crate::data::corpus::LmTask;
use crate::model::{is_projectable, ParamSet, TransformerConfig};
use crate::opt::{BaseOptimizer, FloraCompressor, SubspaceTick, MOMENTUM_BETA};
use crate::rp;
use crate::tensor::Matrix;
use crate::util::rng::derive_seed;
use crate::util::timing::Timer;

/// Split index of the training stream (mirrors `coordinator::task`).
const TRAIN_SPLIT: u64 = 0;

/// Fault injection for the NaN/Inf propagation regression: after the
/// named shard's payload is computed (and before reduction), poison its
/// first two elements of `param` with NaN and +Inf. A poisoned worker
/// must surface in the reduced step — never be averaged away or
/// laundered by a skip — and must do so identically at every worker
/// count. Test-facing; production configs never set it.
#[derive(Clone, Debug)]
pub struct GradFault {
    pub shard: usize,
    pub param: String,
}

/// Per-optimizer-step outcome the trainer reports.
#[derive(Clone, Debug)]
pub struct DpReport {
    /// mean training loss per optimizer step (fixed-order mean over
    /// shards, then over τ micro-steps)
    pub train_losses: Vec<f32>,
    pub ledger: CommsLedger,
    pub wallclock_secs: f64,
    pub steps_per_sec: f64,
}

enum DpMode {
    /// Algorithm 1: τ micro-steps share a cycle seed, accumulate
    /// compressed, decompress once at cycle end (`tau > 1`)
    Accumulation,
    /// Algorithm 2: momentum-in-subspace with κ-resample (`tau == 1`)
    Momentum,
}

/// The dp training loop: shard fan-out → fixed-order reduce → one
/// decompress-and-step, with the comms ledger attached.
pub struct DpTrainer {
    cfg: DpConfig,
    model: TransformerConfig,
    task: LmTask,
    plan: ShardPlan,
    comp: FloraCompressor<Box<dyn BaseOptimizer>>,
    params: ParamSet,
    /// per-parameter base-optimizer state (full-size, like the
    /// single-process runtime — only the *wire* is compressed)
    opt_state: BTreeMap<String, Vec<Matrix>>,
    /// per-parameter method state: compressed accumulator / subspace
    /// momentum `[n, r]` for projectables, full-size for the rest
    method: BTreeMap<String, Matrix>,
    ledger: CommsLedger,
    /// analytic upload bytes of one data step in the configured /
    /// full-exchange modes (one `step_bytes` formula, precomputed)
    bytes_sent_per_step: u64,
    bytes_full_per_step: u64,
    mode: DpMode,
    accum_seeds: AccumSeeds,
    momentum_seeds: MomentumSeeds,
    /// data steps consumed (each = one shard grid row; τ per opt step)
    data_step: u64,
    /// optimizer steps taken
    opt_step: usize,
    fault: Option<GradFault>,
}

impl DpTrainer {
    pub fn new(cfg: DpConfig) -> Result<Self, String> {
        cfg.validate()?;
        cfg.train.parallelism.install();
        let model = Self::lookup_model(&cfg.train.model)?;
        let rank = cfg.rank();
        let base = cfg.train.optimizer.build();
        let comp = FloraCompressor::new(base, rank);
        let seed = cfg.train.seed;
        let task = LmTask::new(model.vocab, model.seq_len, derive_seed(seed, 0xDA7A));
        let params = model.init(seed);
        let mut opt_state = BTreeMap::new();
        let mut method = BTreeMap::new();
        for (name, p) in &params {
            opt_state.insert(name.clone(), comp.base().init_state(p.rows, p.cols));
            let m = if is_projectable(name) {
                Matrix::zeros(p.rows, rank)
            } else {
                Matrix::zeros(p.rows, p.cols)
            };
            method.insert(name.clone(), m);
        }
        let mode = if cfg.train.tau > 1 { DpMode::Accumulation } else { DpMode::Momentum };
        let plan = ShardPlan::new(cfg.shards, cfg.train.batch);
        let shapes = model.param_shapes();
        let bytes_sent_per_step = step_bytes(&shapes, rank, plan.shards, cfg.reduce);
        let bytes_full_per_step = step_bytes(&shapes, rank, plan.shards, ReduceMode::Full);
        Ok(Self {
            accum_seeds: AccumSeeds::new(derive_seed(seed, 0xACC)),
            momentum_seeds: MomentumSeeds::new(derive_seed(seed, 0xE3A), cfg.train.kappa),
            cfg,
            model,
            task,
            plan,
            comp,
            params,
            opt_state,
            method,
            ledger: CommsLedger::default(),
            bytes_sent_per_step,
            bytes_full_per_step,
            mode,
            data_step: 0,
            opt_step: 0,
            fault: None,
        })
    }

    fn lookup_model(name: &str) -> Result<TransformerConfig, String> {
        TransformerConfig::catalog_grid()
            .into_iter()
            .find(|(n, _)| *n == name)
            .map(|(_, c)| c)
            .ok_or_else(|| {
                let names: Vec<&str> =
                    TransformerConfig::catalog_grid().iter().map(|(n, _)| *n).collect();
                format!(
                    "model {name:?} is not dp-capable; train-dp runs the native LM \
                     family: {} (flora --list-catalog marks them)",
                    names.join(" | ")
                )
            })
    }

    pub fn params(&self) -> &ParamSet {
        &self.params
    }

    pub fn ledger(&self) -> &CommsLedger {
        &self.ledger
    }

    /// Install the NaN/Inf fault injection (see [`GradFault`]).
    pub fn inject_fault(&mut self, fault: GradFault) {
        assert!(fault.shard < self.plan.shards, "fault shard out of range");
        self.fault = Some(fault);
    }

    /// One data step: fan shards out over the workers, apply any fault,
    /// account bytes, and reduce in fixed shard order. Returns the
    /// fixed-order mean shard loss and the reduced payload.
    fn reduced_step(
        &mut self,
        mode: ReduceMode,
        proj: StepProjection,
    ) -> Result<(f32, BTreeMap<String, Matrix>), String> {
        let mut grads = run_step_workers(
            &self.model,
            &self.params,
            &self.task,
            &self.plan,
            self.cfg.train.workers,
            TRAIN_SPLIT,
            self.data_step,
            mode,
            proj,
        )?;
        self.data_step += 1;
        if let Some(f) = &self.fault {
            let payload = &mut grads[f.shard].payload;
            let m = payload.get_mut(&f.param).ok_or_else(|| {
                format!("fault injection: no parameter {:?} in the payload", f.param)
            })?;
            m.data[0] = f32::NAN;
            if m.data.len() > 1 {
                m.data[1] = f32::INFINITY;
            }
        }
        self.ledger.record_step(self.bytes_sent_per_step, self.bytes_full_per_step);
        // fixed-order loss mean: ascending shard order, then one divide
        let mut loss_sum = 0.0f32;
        for g in &grads {
            loss_sum += g.loss;
        }
        let loss = loss_sum / self.plan.shards as f32;
        let payloads: Vec<BTreeMap<String, Matrix>> =
            grads.into_iter().map(|g| g.payload).collect();
        Ok((loss, reduce_fixed_order(&payloads)))
    }

    /// One optimizer step (τ data steps in accumulation mode).
    pub fn train_step(&mut self) -> Result<f32, String> {
        let mode = self.cfg.reduce;
        let rank = self.cfg.rank();
        let lr = self.cfg.train.lr;
        let step_f = self.opt_step as f32;
        let shards_f = self.plan.shards as f32;
        let loss = match self.mode {
            DpMode::Accumulation => {
                let tau = self.cfg.train.tau;
                let cycle_seed = self.accum_seeds.current() as u64;
                let proj = StepProjection { rank, cycle_seed };
                let mut loss_sum = 0.0f32;
                for _micro in 0..tau {
                    let (loss, reduced) = self.reduced_step(mode, proj)?;
                    loss_sum += loss;
                    // fold the reduced payload into the accumulators;
                    // under `full` reduce the projectables are compressed
                    // HERE (post-reduction) instead of on the workers —
                    // same optimizer semantics, ~d/r× the bytes
                    for (idx, (name, r)) in reduced.iter().enumerate() {
                        let acc = self.method.get_mut(name).expect("method state");
                        if is_projectable(name) && mode == ReduceMode::Full {
                            self.comp.accumulate(acc, r, rp::param_seed(cycle_seed, idx));
                        } else {
                            acc.add_scaled_inplace(r, 1.0);
                        }
                    }
                }
                // cycle end: decompress ÷(τ·S) — each reduced payload was
                // a SUM over shards of shard-means — and base-step
                for (idx, (name, w)) in self.params.iter_mut().enumerate() {
                    let acc = self.method.get_mut(name).expect("method state");
                    let st = self.opt_state.get_mut(name).expect("opt state");
                    if is_projectable(name) {
                        self.comp.apply_accumulated(
                            w,
                            acc,
                            st,
                            rp::param_seed(cycle_seed, idx),
                            (tau * self.plan.shards) as f32,
                            lr,
                            step_f,
                        )?;
                    } else {
                        let ghat = acc.scale(1.0 / (tau as f32 * shards_f));
                        self.comp.base().update(w, &ghat, st, lr, step_f)?;
                    }
                    *acc = Matrix::zeros(acc.rows, acc.cols);
                }
                self.accum_seeds.advance();
                loss_sum / tau as f32
            }
            DpMode::Momentum => {
                let tick = self.momentum_seeds.tick();
                let resample = tick.resample > 0.5;
                let active = if resample { tick.seed_next } else { tick.seed_cur } as u64;
                let proj = StepProjection { rank, cycle_seed: active };
                let (loss, reduced) = self.reduced_step(mode, proj)?;
                for (idx, (name, w)) in self.params.iter_mut().enumerate() {
                    let r = &reduced[name];
                    let mom = self.method.get_mut(name).expect("method state");
                    let st = self.opt_state.get_mut(name).expect("opt state");
                    if is_projectable(name) {
                        let ptick = SubspaceTick {
                            seed_cur: rp::param_seed(tick.seed_cur as u64, idx),
                            seed_next: rp::param_seed(tick.seed_next as u64, idx),
                            resample,
                            transfer: true,
                        };
                        // mean over shards; under `full` reduce, compress
                        // the mean with the ACTIVE projection first
                        let c = if mode == ReduceMode::Full {
                            let a = self
                                .comp
                                .projection(rp::param_seed(active, idx), w.cols);
                            rp::compress(&r.scale(1.0 / shards_f), &a)
                        } else {
                            r.scale(1.0 / shards_f)
                        };
                        self.comp
                            .momentum_step_compressed(w, mom, st, &c, ptick, lr, step_f)?;
                    } else {
                        // full-space EMA, exactly as the single-process
                        // native runtime treats non-projectables
                        let g = r.scale(1.0 / shards_f);
                        let mut next = mom.scale(MOMENTUM_BETA);
                        next.add_scaled_inplace(&g, 1.0 - MOMENTUM_BETA);
                        self.comp.base().update(w, &next, st, lr, step_f)?;
                        *mom = next;
                    }
                }
                loss
            }
        };
        self.opt_step += 1;
        Ok(loss)
    }

    /// Train for the configured number of optimizer steps.
    pub fn run(&mut self) -> Result<DpReport, String> {
        let timer = Timer::start();
        let steps = self.cfg.train.steps;
        let mut train_losses = Vec::with_capacity(steps);
        for _ in 0..steps {
            train_losses.push(self.train_step()?);
        }
        let wallclock_secs = timer.elapsed_secs();
        Ok(DpReport {
            train_losses,
            ledger: self.ledger,
            wallclock_secs,
            steps_per_sec: if wallclock_secs > 0.0 { steps as f64 / wallclock_secs } else { 0.0 },
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::opt::OptimizerKind;
    use crate::tensor::Parallelism;

    fn tiny_cfg(workers: usize, steps: usize) -> DpConfig {
        let mut cfg = DpConfig::default();
        cfg.train.workers = workers;
        cfg.train.steps = steps;
        cfg.train.optimizer = OptimizerKind::Sgd;
        cfg.train.parallelism = Parallelism::single();
        cfg
    }

    #[test]
    fn trainer_runs_and_ledger_counts_every_data_step() {
        let mut t = DpTrainer::new(tiny_cfg(1, 3)).unwrap();
        let report = t.run().unwrap();
        assert_eq!(report.train_losses.len(), 3);
        assert!(report.train_losses.iter().all(|l| l.is_finite()));
        // tau = 1: one data step per optimizer step
        assert_eq!(report.ledger.steps, 3);
        assert!(report.ledger.bytes_sent < report.ledger.bytes_full);
    }

    #[test]
    fn unknown_model_error_names_the_dp_capable_family() {
        let mut cfg = tiny_cfg(1, 1);
        cfg.train.model = "lm-small".into();
        let e = DpTrainer::new(cfg).unwrap_err();
        assert!(e.contains("lora-tiny"), "{e}");
        assert!(e.contains("list-catalog"), "{e}");
    }

    #[test]
    fn accumulation_mode_consumes_tau_data_steps() {
        let mut cfg = tiny_cfg(1, 2);
        cfg.train.tau = 3;
        let mut t = DpTrainer::new(cfg).unwrap();
        let report = t.run().unwrap();
        assert_eq!(report.ledger.steps, 6, "2 opt steps x tau 3 data steps");
    }
}
