//! PJRT execution backend (the original XLA path), behind the `xla` cargo
//! feature: loads AOT artifacts (`artifacts/*.hlo.txt` + `manifest.json`)
//! and executes them on the CPU PJRT client via the vendored `xla` crate.
//! This is the only module that touches XLA; everything above works with
//! backend-neutral `Tensor` groups described by the manifest.
//!
//! Interchange is HLO **text** — xla_extension 0.5.1 rejects jax≥0.5
//! serialized protos (64-bit instruction ids); the text parser reassigns
//! ids (see /opt/xla-example/README.md and DESIGN.md §8).
//!
//! NOTE: the `xla` crate is not on crates.io. Building with `--features
//! xla` requires the offline-vendored crate to be supplied via a `[patch]`
//! entry or vendor directory (see README "Backends").

use std::rc::Rc;
use std::time::Instant;

use xla::{Literal, PjRtClient, PjRtLoadedExecutable};

use super::backend::{Backend, BackendExec};
use super::manifest::{ExecutableInfo, TensorSpec};
use super::values::Tensor;
use crate::debug;

/// The PJRT engine: one CPU client shared by all compiled executables.
pub struct PjrtBackend {
    client: PjRtClient,
}

impl PjrtBackend {
    pub fn new() -> Result<Self, String> {
        let client =
            PjRtClient::cpu().map_err(|e| format!("PjRtClient::cpu: {e:?}"))?;
        debug!("pjrt client up: platform={}", client.platform_name());
        Ok(Self { client })
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn compile(
        &mut self,
        info: &ExecutableInfo,
    ) -> Result<Rc<dyn BackendExec>, String> {
        let name = &info.name;
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            info.file
                .to_str()
                .ok_or_else(|| format!("{name}: non-utf8 path"))?,
        )
        .map_err(|e| format!("{name}: parse HLO text: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| format!("{name}: compile: {e:?}"))?;
        debug!("compiled {name} in {:.2}s", t0.elapsed().as_secs_f64());
        Ok(Rc::new(PjrtExec {
            name: name.clone(),
            outputs: info.outputs.clone(),
            exe,
        }) as Rc<dyn BackendExec>)
    }
}

/// A compiled PJRT executable; converts `Tensor` ↔ `Literal` at the edge.
struct PjrtExec {
    name: String,
    outputs: Vec<TensorSpec>,
    exe: PjRtLoadedExecutable,
}

impl BackendExec for PjrtExec {
    fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>, String> {
        let lits = inputs
            .iter()
            .map(to_literal)
            .collect::<Result<Vec<_>, _>>()?;
        let bufs = self
            .exe
            .execute::<Literal>(&lits)
            .map_err(|e| format!("{}: execute: {e:?}", self.name))?;
        let result = bufs[0][0]
            .to_literal_sync()
            .map_err(|e| format!("{}: to_literal: {e:?}", self.name))?;
        let outputs = result
            .to_tuple()
            .map_err(|e| format!("{}: untuple: {e:?}", self.name))?;
        if outputs.len() != self.outputs.len() {
            return Err(format!(
                "{}: got {} outputs, manifest wants {}",
                self.name,
                outputs.len(),
                self.outputs.len()
            ));
        }
        outputs
            .iter()
            .zip(self.outputs.iter())
            .map(|(l, s)| from_literal(l, s))
            .collect()
    }
}

fn shaped<T: xla::ArrayElement + xla::NativeType>(
    data: &[T],
    shape: &[usize],
) -> Result<Literal, String> {
    if shape.is_empty() {
        return Ok(Literal::scalar(data[0]));
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Literal::vec1(data)
        .reshape(&dims)
        .map_err(|e| format!("reshape: {e:?}"))
}

/// Host tensor → PJRT literal.
fn to_literal(t: &Tensor) -> Result<Literal, String> {
    match t {
        Tensor::F32 { shape, data } => shaped(data, shape),
        Tensor::I32 { shape, data } => shaped(data, shape),
        Tensor::U32 { shape, data } => shaped(data, shape),
    }
}

/// PJRT literal → host tensor, typed by the manifest output spec.
fn from_literal(l: &Literal, spec: &TensorSpec) -> Result<Tensor, String> {
    let ctx = &spec.name;
    match spec.dtype.as_str() {
        "int32" => Ok(Tensor::I32 {
            shape: spec.shape.clone(),
            data: l
                .to_vec::<i32>()
                .map_err(|e| format!("{ctx}: to_vec i32: {e:?}"))?,
        }),
        "uint32" => Ok(Tensor::U32 {
            shape: spec.shape.clone(),
            data: l
                .to_vec::<u32>()
                .map_err(|e| format!("{ctx}: to_vec u32: {e:?}"))?,
        }),
        _ => Ok(Tensor::F32 {
            shape: spec.shape.clone(),
            data: l
                .to_vec::<f32>()
                .map_err(|e| format!("{ctx}: to_vec f32: {e:?}"))?,
        }),
    }
}
