//! `flora serve`'s execution core: a request queue with dynamic batching
//! (max-batch + max-wait policy), and a driver that runs each formed
//! batch through the KV-cache multi-adapter decode
//! (`model::decode::serve_greedy`).
//!
//! Batches are **shape-homogeneous**: the batcher only groups requests
//! that share `(prompt_len, max_new)`. The alternative — padding ragged
//! prompts — would change the GEMM row sets and could flip `-0.0` sums
//! to `+0.0`, breaking the tier's bit-compare oracle; grouping by shape
//! keeps every batched request bit-identical to its solo run (the
//! latency cost of waiting for shape-mates is bounded by `max_wait_ms`).
//! Adapter-rank homogeneity is the registry's job
//! ([`AdapterRegistry`](super::AdapterRegistry) pins one rank), so any
//! mix of *adapters* can share a batch — that is the whole point.
//!
//! Time is a caller-supplied millisecond clock, so batching policy is
//! deterministic and unit-testable; `flora serve` feeds it a synthetic
//! arrival schedule, wall-clock only enters the measured latencies.

use super::adapters::AdapterRegistry;
use crate::model::decode::{serve_greedy, serve_prefill};
use crate::model::{AdapterParams, ParamSet, TransformerConfig};
use std::collections::VecDeque;

/// One inference request: decode `max_new` tokens greedily after
/// `prompt`, under the named adapter.
#[derive(Clone, Debug)]
pub struct ServeRequest {
    pub id: u64,
    pub adapter: String,
    pub prompt: Vec<i32>,
    pub max_new: usize,
    pub arrival_ms: u64,
}

/// A finished request: the full token stream (prompt + continuation)
/// plus the batching telemetry the bench records.
#[derive(Clone, Debug)]
pub struct ServeResponse {
    pub id: u64,
    pub adapter: String,
    pub tokens: Vec<i32>,
    /// generated suffix length
    pub new_tokens: usize,
    /// time spent queued before the batch formed
    pub queue_ms: u64,
    /// size of the batch this request decoded in
    pub batch_size: usize,
}

/// Dynamic-batching policy: close a batch as soon as `max_batch`
/// shape-compatible requests are queued, or once the oldest has waited
/// `max_wait_ms` — the standard latency/throughput dial
/// (`docs/SERVING.md` §3).
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait_ms: u64,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        Self { max_batch: 4, max_wait_ms: 50 }
    }
}

/// FIFO request queue + batch former. Purely synchronous: `push`
/// enqueues, [`form_batch`](Batcher::form_batch) decides.
pub struct Batcher {
    policy: BatchPolicy,
    queue: VecDeque<ServeRequest>,
}

impl Batcher {
    pub fn new(policy: BatchPolicy) -> Self {
        assert!(policy.max_batch >= 1, "max_batch must be >= 1");
        Self { policy, queue: VecDeque::new() }
    }

    pub fn push(&mut self, req: ServeRequest) {
        self.queue.push_back(req);
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Try to close a batch at `now_ms`: take the oldest request plus
    /// every queued shape-mate (same `(prompt_len, max_new)`), FIFO, up
    /// to `max_batch`. Returns `None` while the group is still short of
    /// `max_batch` AND the oldest request has waited under
    /// `max_wait_ms` — unless `force` (drain/shutdown) is set.
    pub fn form_batch(&mut self, now_ms: u64, force: bool) -> Option<Vec<ServeRequest>> {
        let head = self.queue.front()?;
        let key = (head.prompt.len(), head.max_new);
        let group = self
            .queue
            .iter()
            .filter(|r| (r.prompt.len(), r.max_new) == key)
            .count()
            .min(self.policy.max_batch);
        let waited = now_ms.saturating_sub(head.arrival_ms);
        if group < self.policy.max_batch && waited < self.policy.max_wait_ms && !force {
            return None;
        }
        let mut batch = Vec::with_capacity(group);
        let mut rest = VecDeque::with_capacity(self.queue.len() - group);
        while let Some(r) = self.queue.pop_front() {
            if batch.len() < self.policy.max_batch && (r.prompt.len(), r.max_new) == key {
                batch.push(r);
            } else {
                rest.push_back(r);
            }
        }
        self.queue = rest;
        Some(batch)
    }
}

/// Telemetry for one executed batch.
#[derive(Clone, Debug)]
pub struct BatchReport {
    pub batch_size: usize,
    pub prompt_len: usize,
    pub new_tokens: usize,
    pub adapters: Vec<String>,
}

/// The one-process serve driver: owns the base weights, the adapter
/// registry and the batcher; [`step`](Server::step) forms and executes
/// one batch, [`drain`](Server::drain) flushes the queue.
pub struct Server {
    cfg: TransformerConfig,
    base: ParamSet,
    pub registry: AdapterRegistry,
    batcher: Batcher,
    next_id: u64,
    responses: Vec<ServeResponse>,
}

impl Server {
    pub fn new(
        cfg: TransformerConfig,
        base: ParamSet,
        registry: AdapterRegistry,
        policy: BatchPolicy,
    ) -> Self {
        Self { cfg, base, registry, batcher: Batcher::new(policy), next_id: 0, responses: Vec::new() }
    }

    pub fn config(&self) -> &TransformerConfig {
        &self.cfg
    }

    /// Enqueue a request; returns its id. Validates shape and adapter
    /// residency up front so malformed requests fail at submission, not
    /// mid-batch.
    pub fn submit(
        &mut self,
        adapter: &str,
        prompt: Vec<i32>,
        max_new: usize,
        now_ms: u64,
    ) -> Result<u64, String> {
        if prompt.is_empty() {
            return Err("empty prompt".into());
        }
        if max_new == 0 {
            return Err("max_new must be >= 1".into());
        }
        if prompt.len() + max_new > self.cfg.seq_len {
            return Err(format!(
                "prompt {} + max_new {max_new} exceeds seq_len {}",
                prompt.len(),
                self.cfg.seq_len
            ));
        }
        for &t in &prompt {
            if t < 0 || t as usize >= self.cfg.vocab {
                return Err(format!("token id {t} out of range for vocab {}", self.cfg.vocab));
            }
        }
        if !self.registry.contains(adapter) {
            return Err(format!("adapter {adapter:?} is not resident"));
        }
        let id = self.next_id;
        self.next_id += 1;
        self.batcher.push(ServeRequest {
            id,
            adapter: adapter.to_string(),
            prompt,
            max_new,
            arrival_ms: now_ms,
        });
        Ok(id)
    }

    pub fn pending(&self) -> usize {
        self.batcher.pending()
    }

    /// Form and execute at most one batch at `now_ms`. Returns the
    /// batch's telemetry, or `None` if the policy kept the queue open.
    pub fn step(&mut self, now_ms: u64, force: bool) -> Result<Option<BatchReport>, String> {
        let Some(batch) = self.batcher.form_batch(now_ms, force) else {
            return Ok(None);
        };
        let b = batch.len();
        let prompt_len = batch[0].prompt.len();
        let max_new = batch[0].max_new;
        let s = prompt_len + max_new;
        let mut tokens = vec![0i32; b * s];
        for (bi, r) in batch.iter().enumerate() {
            tokens[bi * s..bi * s + prompt_len].copy_from_slice(&r.prompt);
        }
        let names: Vec<String> = batch.iter().map(|r| r.adapter.clone()).collect();
        {
            let adapters = self.registry.get_many(&names)?;
            serve_greedy(&self.cfg, &self.base, &adapters, &mut tokens, s, prompt_len)?;
        }
        for (bi, r) in batch.iter().enumerate() {
            self.responses.push(ServeResponse {
                id: r.id,
                adapter: r.adapter.clone(),
                tokens: tokens[bi * s..(bi + 1) * s].to_vec(),
                new_tokens: max_new,
                queue_ms: now_ms.saturating_sub(r.arrival_ms),
                batch_size: b,
            });
        }
        Ok(Some(BatchReport { batch_size: b, prompt_len, new_tokens: max_new, adapters: names }))
    }

    /// Flush the queue (force-forming batches) and return how many
    /// batches ran.
    pub fn drain(&mut self, now_ms: u64) -> Result<usize, String> {
        let mut n = 0;
        while self.step(now_ms, true)?.is_some() {
            n += 1;
        }
        Ok(n)
    }

    /// Take all finished responses accumulated so far.
    pub fn take_responses(&mut self) -> Vec<ServeResponse> {
        std::mem::take(&mut self.responses)
    }
}

/// The serving tier's bit-compare oracle: run one batch of prompts with
/// per-request adapters BOTH batched and as single-request forwards, and
/// require (a) prefill activations byte-identical per request, and
/// (b) greedy token streams identical. Returns the batched streams.
///
/// This is the acceptance gate `flora serve --verify` and the CI smoke
/// job run; the integration suite calls it with NaN/Inf-poisoned
/// adapters too.
pub fn oracle_check(
    cfg: &TransformerConfig,
    base: &ParamSet,
    adapters: &[&AdapterParams],
    prompts: &[Vec<i32>],
    max_new: usize,
) -> Result<Vec<Vec<i32>>, String> {
    let b = adapters.len();
    if b == 0 || prompts.len() != b {
        return Err(format!("oracle_check: {} adapters vs {} prompts", b, prompts.len()));
    }
    let prompt_len = prompts[0].len();
    if prompts.iter().any(|p| p.len() != prompt_len) {
        return Err("oracle_check: ragged prompts (batches are shape-homogeneous)".into());
    }
    let s = prompt_len + max_new;
    let mut tokens = vec![0i32; b * s];
    for (bi, p) in prompts.iter().enumerate() {
        tokens[bi * s..bi * s + prompt_len].copy_from_slice(p);
    }
    // (a) prefill activations: batched vs per-request, exact bits
    let batched = serve_prefill(cfg, base, adapters, &tokens, s)?;
    let d = cfg.dims.d_model;
    for bi in 0..b {
        let solo =
            serve_prefill(cfg, base, &adapters[bi..bi + 1], &tokens[bi * s..(bi + 1) * s], s)?;
        let panel = &batched.data[bi * s * d..(bi + 1) * s * d];
        for (j, (g, w)) in panel.iter().zip(solo.data.iter()).enumerate() {
            if g.to_bits() != w.to_bits() {
                return Err(format!(
                    "prefill mismatch: request {bi} element {j}: batched {g:?} vs solo {w:?}"
                ));
            }
        }
    }
    // (b) decoded token streams: batched vs per-request
    let mut batch_toks = tokens.clone();
    serve_greedy(cfg, base, adapters, &mut batch_toks, s, prompt_len)?;
    let mut out = Vec::with_capacity(b);
    for bi in 0..b {
        let mut solo = tokens[bi * s..(bi + 1) * s].to_vec();
        serve_greedy(cfg, base, &adapters[bi..bi + 1], &mut solo, s, prompt_len)?;
        if batch_toks[bi * s..(bi + 1) * s] != solo[..] {
            return Err(format!(
                "decode mismatch: request {bi}: batched {:?} vs solo {:?}",
                &batch_toks[bi * s..(bi + 1) * s],
                &solo
            ));
        }
        out.push(solo);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, plen: usize, max_new: usize, at: u64) -> ServeRequest {
        ServeRequest {
            id,
            adapter: format!("a{id}"),
            prompt: vec![1; plen],
            max_new,
            arrival_ms: at,
        }
    }

    #[test]
    fn batcher_waits_then_fires_on_max_wait() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 4, max_wait_ms: 50 });
        b.push(req(0, 4, 2, 100));
        b.push(req(1, 4, 2, 110));
        assert!(b.form_batch(120, false).is_none(), "under max_wait with a short group");
        let batch = b.form_batch(150, false).expect("max_wait elapsed");
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1]);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn batcher_fires_immediately_at_max_batch() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 2, max_wait_ms: 1000 });
        b.push(req(0, 4, 2, 0));
        b.push(req(1, 4, 2, 0));
        b.push(req(2, 4, 2, 0));
        let batch = b.form_batch(0, false).expect("max_batch reached");
        assert_eq!(batch.len(), 2);
        assert_eq!(b.pending(), 1);
    }

    #[test]
    fn batcher_groups_by_shape_only() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 4, max_wait_ms: 0 });
        b.push(req(0, 4, 2, 0));
        b.push(req(1, 6, 2, 0)); // different prompt_len
        b.push(req(2, 4, 3, 0)); // different max_new
        b.push(req(3, 4, 2, 0)); // shape-mate of 0
        let batch = b.form_batch(0, false).unwrap();
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 3]);
        // the others stay queued in order
        assert_eq!(b.pending(), 2);
        let next = b.form_batch(0, false).unwrap();
        assert_eq!(next[0].id, 1);
    }

    #[test]
    fn empty_queue_never_forms() {
        let mut b = Batcher::new(BatchPolicy::default());
        assert!(b.form_batch(1 << 40, true).is_none());
    }

    fn demo_server(max_batch: usize) -> Server {
        let cfg = TransformerConfig::tiny();
        let base = cfg.init(0);
        let mut reg = AdapterRegistry::new(8);
        for i in 0..3 {
            reg.insert_synthetic(&format!("ad{i}"), &cfg, &base, 4, 10 + i as u64).unwrap();
        }
        Server::new(cfg, base, reg, BatchPolicy { max_batch, max_wait_ms: 50 })
    }

    #[test]
    fn server_answers_mixed_adapter_batches() {
        let mut srv = demo_server(4);
        let plen = 8;
        let mut ids = Vec::new();
        for i in 0..3u64 {
            let prompt: Vec<i32> = (0..plen).map(|j| ((3 + i as usize + 2 * j) % 64) as i32).collect();
            ids.push(srv.submit(&format!("ad{i}"), prompt, 4, i * 5).unwrap());
        }
        assert!(srv.step(10, false).unwrap().is_none(), "policy holds the batch open");
        let report = srv.step(60, false).unwrap().expect("max_wait elapsed");
        assert_eq!(report.batch_size, 3);
        assert_eq!(report.adapters, vec!["ad0", "ad1", "ad2"]);
        let resp = srv.take_responses();
        assert_eq!(resp.len(), 3);
        for (r, id) in resp.iter().zip(&ids) {
            assert_eq!(r.id, *id);
            assert_eq!(r.tokens.len(), plen + 4);
            assert_eq!(r.batch_size, 3);
            // the prompt region is preserved verbatim
            assert!(r.tokens[..plen].iter().all(|&t| (0..64).contains(&t)));
        }
        // each response bit-matches a solo rerun of the same request
        for r in &resp {
            let mut solo_reg = AdapterRegistry::new(8);
            let cfg = TransformerConfig::tiny();
            let base = cfg.init(0);
            solo_reg
                .insert_synthetic(&r.adapter, &cfg, &base, 4, 10 + r.adapter[2..].parse::<u64>().unwrap())
                .unwrap();
            let mut solo = Server::new(cfg, base, solo_reg, BatchPolicy { max_batch: 1, max_wait_ms: 0 });
            solo.submit(&r.adapter, r.tokens[..plen].to_vec(), 4, 0).unwrap();
            solo.drain(0).unwrap();
            let sr = solo.take_responses();
            assert_eq!(sr[0].tokens, r.tokens, "adapter {}", r.adapter);
        }
    }

    #[test]
    fn server_rejects_bad_submissions() {
        let mut srv = demo_server(4);
        assert!(srv.submit("ad0", vec![], 2, 0).is_err());
        assert!(srv.submit("ad0", vec![1; 4], 0, 0).is_err());
        assert!(srv.submit("ad0", vec![1; 20], 4, 0).is_err(), "overflows seq_len");
        assert!(srv.submit("ad0", vec![-3; 4], 2, 0).is_err());
        assert!(srv.submit("ghost", vec![1; 4], 2, 0).is_err());
    }

    #[test]
    fn drain_flushes_mixed_shapes_as_separate_batches() {
        let mut srv = demo_server(4);
        srv.submit("ad0", vec![1; 4], 2, 0).unwrap();
        srv.submit("ad1", vec![1; 6], 2, 0).unwrap();
        srv.submit("ad2", vec![1; 4], 2, 0).unwrap();
        let batches = srv.drain(0).unwrap();
        assert_eq!(batches, 2, "two shape groups");
        assert_eq!(srv.take_responses().len(), 3);
        assert_eq!(srv.pending(), 0);
    }

    #[test]
    fn oracle_check_passes_on_served_traffic() {
        let cfg = TransformerConfig::tiny();
        let base = cfg.init(0);
        let mut reg = AdapterRegistry::new(8);
        for i in 0..3 {
            reg.insert_synthetic(&format!("ad{i}"), &cfg, &base, 4, 40 + i as u64).unwrap();
        }
        let names: Vec<String> = (0..3).map(|i| format!("ad{i}")).collect();
        let adapters = reg.get_many(&names).unwrap();
        let prompts: Vec<Vec<i32>> =
            (0..3).map(|i| (0..8).map(|j| ((5 + i + 3 * j) % 64) as i32).collect()).collect();
        let streams = oracle_check(&cfg, &base, &adapters, &prompts, 4).unwrap();
        assert_eq!(streams.len(), 3);
        assert!(streams.iter().all(|s| s.len() == 12));
    }
}
