//! Native execution backend: a pure-rust executor for a generated catalog
//! of executables implementing the manifest ABI's fused steps — plain
//! steps, Algorithm-1 accumulation (micro + cycle-end update), Algorithm-2
//! momentum with κ-interval subspace transfer, and the GaLore
//! refresh-projection baseline — directly on `tensor::Matrix` with ALL
//! optimizer math delegated to the shared [`crate::opt`] layer
//! ([`BaseOptimizer`] + [`FloraCompressor`]). Adding a base optimizer is
//! one trait impl plus one [`OptimizerKind`] variant; the catalog then
//! grows its `*_{optimizer}` step names automatically.
//!
//! The native catalog carries TWO model families:
//!
//!   * the seeded BIGRAM language models (`lm-tiny`/`lm-small`/`lm-base`):
//!     a single `[vocab, vocab]` next-token logit table trained with
//!     masked softmax cross-entropy — deliberately the smallest model with
//!     a 2-D gradient, because FLORA's subject is the *gradient pipeline*;
//!   * the pure-rust TRANSFORMERS from [`crate::model`], each a SIZE
//!     GRID like the bigram models: the causal LMs
//!     `lora-tiny`/`lora-small`/`lora-base` (full-tune, LoRA-adapter and
//!     GaLore entries) and the ViTs `vit-tiny`/`vit-small` (Table-5
//!     workload), all with manual backward passes on the batched
//!     attention kernels, so the paper's LoRA and ViT experiments run —
//!     and sweep sizes — XLA-free. On multi-matrix parameter sets every
//!     projectable (attention/MLP) matrix gets an independent
//!     per-parameter projection seed; the embeddings/norms/heads follow
//!     the paper's "naive procedure".
//!
//! The coordinator above cannot tell the families apart — it sees the
//! same manifest groups, scalars and executable names either way.
//!
//! Deviations from the AOT catalog, by design:
//!   * the GaLore refresh regenerates the STORED projection from the seed
//!     (a JL subspace) instead of an SVD of the gradient; the memory and
//!     scheduling semantics the coordinator exercises (P lives in state,
//!     moments live in the subspace, refresh every κ steps) are identical.
//!   * the per-model rank grids differ (`RANKS` vs `TF_RANKS`).

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::rc::Rc;

use super::backend::{Backend, BackendExec};
use super::manifest::{ExecutableInfo, Manifest, ModelInfo, TensorSpec};
use super::values::{scalar_f32, Tensor};
use crate::model::{
    is_projectable, LoraAdapter, ParamSet, TransformerConfig, VitConfig,
};
use crate::opt::{
    Adam, AltLoraCompressor, BaseOptimizer, CompressorKind, FloraCompressor,
    OptimizerKind, RankSchedule, RankedTick, ScheduledFlora, SubspaceTick,
    MOMENTUM_BETA,
};
use crate::rp;
use crate::tensor::Matrix;
use crate::util::rng::{derive_seed, Rng};

/// Init scale of the logit table (small ⇒ near-uniform initial loss ln v).
const INIT_SIGMA: f32 = 0.05;
/// Ranks the generated catalog covers — a dense-enough grid for the bench
/// rank sweeps; the manifest is generated, so extending this is one edit.
const RANKS: [usize; 7] = [1, 2, 4, 8, 16, 32, 64];
/// Batch dimension advertised in the generated specs. The executor reads
/// the REAL batch from the input tensors at run time; the spec value only
/// matters to readers that size buffers from the manifest (greedy eval).
const SPEC_BATCH: usize = 4;
/// (name, vocab, seq_len) of the native model grid; vocab doubles as the
/// side of the logit table.
const MODELS: [(&str, usize, usize); 3] =
    [("lm-tiny", 64, 32), ("lm-small", 256, 64), ("lm-base", 512, 64)];

/// Ranks of the transformer-family entries (every `lora-*`/`vit-*`
/// size; 32 is full-rank on the tiny models' d_model and a 1/4 ratio on
/// `lora-base`).
const TF_RANKS: [usize; 4] = [4, 8, 16, 32];

/// Which fused step a native executable performs. Update-bearing steps
/// carry the [`OptimizerKind`] whose [`crate::opt::BaseOptimizer`] does
/// the actual math. `Tf*`/`Lora*`/`Vit*` are the transformer-family
/// mirrors of the bigram steps, operating on multi-matrix parameter sets.
#[derive(Clone, Copy, Debug)]
enum Step {
    Init,
    Eval,
    Greedy,
    Plain { opt: OptimizerKind },
    MicroFlora { rank: usize },
    MicroNaive,
    UpdateFlora { rank: usize, opt: OptimizerKind },
    UpdateNaive { opt: OptimizerKind },
    MomFlora { rank: usize, transfer: bool, opt: OptimizerKind },
    MomNaive { opt: OptimizerKind },
    GaloreStep { rank: usize },
    // transformer LM (lora-tiny) — full-tune paths
    TfInit,
    TfEval,
    TfGreedy,
    TfPlain { opt: OptimizerKind },
    TfMicroFlora { rank: usize },
    TfMicroNaive,
    TfUpdateFlora { rank: usize, opt: OptimizerKind },
    TfUpdateNaive { opt: OptimizerKind },
    TfMomFlora { rank: usize, transfer: bool, opt: OptimizerKind },
    TfMomNaive { opt: OptimizerKind },
    TfGalore { rank: usize },
    // transformer LM — adaptive-rank compressor grid
    TfMicroAlt { rank: usize },
    TfUpdateAlt { rank: usize, opt: OptimizerKind },
    TfMomAdaRank { rank: usize, opt: OptimizerKind },
    // transformer LM — LoRA adapter baseline (frozen base + patches)
    LoraInit { rank: usize },
    LoraMicro { rank: usize },
    LoraUpdate { rank: usize, opt: OptimizerKind },
    LoraMom { rank: usize, opt: OptimizerKind },
    LoraEval { rank: usize },
    LoraGreedy { rank: usize },
    // ViT (vit-tiny) — Table-5 steps
    VitInit,
    VitEval,
    VitPlain { opt: OptimizerKind },
    VitMomFlora { rank: usize, opt: OptimizerKind },
    VitAltStep { rank: usize, opt: OptimizerKind },
    VitAdaRank { rank: usize, opt: OptimizerKind },
}

/// Which model family an executable belongs to (and its configuration).
#[derive(Clone, Debug)]
enum Family {
    Bigram { vocab: usize },
    Lm(TransformerConfig),
    Vit(VitConfig),
}

/// One natively-executable catalog entry. Keeps its input specs so the
/// executor can route inputs by ABI name, mirroring the coordinator side.
struct NativeExec {
    name: String,
    family: Family,
    step: Step,
    inputs: Vec<TensorSpec>,
}

impl NativeExec {
    fn bigram_vocab(&self) -> Result<usize, String> {
        match &self.family {
            Family::Bigram { vocab } => Ok(*vocab),
            _ => Err(format!("{}: not a bigram executable", self.name)),
        }
    }

    fn lm_cfg(&self) -> Result<TransformerConfig, String> {
        match &self.family {
            Family::Lm(cfg) => Ok(*cfg),
            _ => Err(format!("{}: not a transformer-lm executable", self.name)),
        }
    }

    fn vit_cfg(&self) -> Result<VitConfig, String> {
        match &self.family {
            Family::Vit(cfg) => Ok(*cfg),
            _ => Err(format!("{}: not a vit executable", self.name)),
        }
    }
}

/// The native engine: executables are prepared at catalog build time, so
/// "compiling" is a map lookup.
pub struct NativeBackend {
    execs: BTreeMap<String, Rc<NativeExec>>,
    /// distinct model names, for the compile error message
    families: Vec<String>,
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn compile(
        &mut self,
        info: &ExecutableInfo,
    ) -> Result<Rc<dyn BackendExec>, String> {
        let e = self.execs.get(&info.name).ok_or_else(|| {
            format!(
                "{}: not a native executable (catalog models: {}; every \
                 base optimizer sgd|adam|adafactor|adafactor_nofactor, lm \
                 ranks {RANKS:?}, transformer ranks {TF_RANKS:?} — run \
                 `flora --list-catalog` for the full inventory)",
                info.name,
                self.families.join(", "),
            )
        })?;
        Ok(e.clone() as Rc<dyn BackendExec>)
    }
}

/// The generated manifest alone (CLI `inspect --backend native`).
pub fn native_manifest() -> Manifest {
    catalog().0
}

/// Human-readable catalog inventory grouped by model family (`lm` /
/// `lora` / `vit`) and size (smallest first), with the rank and
/// base-optimizer variants of each step collapsed into `r{N}` / `{opt}`
/// patterns — what `flora --list-catalog` prints. Grouping is what keeps
/// the size-grid catalog readable: hundreds of executables collapse to a
/// dozen step patterns per model.
pub fn catalog_summary(manifest: &Manifest) -> String {
    use std::fmt::Write as _;
    let mut out = String::new();
    let _ = writeln!(
        out,
        "native catalog: {} models, {} executables",
        manifest.models.len(),
        manifest.executables.len()
    );
    // family = the model-name prefix before the first '-'
    let mut families: BTreeMap<&str, Vec<&ModelInfo>> = BTreeMap::new();
    for info in manifest.models.values() {
        let fam = info.name.split('-').next().unwrap_or(&info.name);
        families.entry(fam).or_default().push(info);
    }
    // models `flora train-dp` can shard (the native transformer LM grid)
    let dp_capable: Vec<&str> = crate::model::TransformerConfig::catalog_grid()
        .iter()
        .map(|(n, _)| *n)
        .collect();
    let mut any_dp = false;
    for (fam, mut infos) in families {
        infos.sort_by_key(|m| {
            (m.get("d_model").unwrap_or(0), m.get("vocab").unwrap_or(0), m.name.clone())
        });
        let names: Vec<&str> = infos.iter().map(|m| m.name.as_str()).collect();
        let _ = writeln!(out, "\n{fam} family (sizes: {}):", names.join(" < "));
        for info in &infos {
            let mut patterns: BTreeMap<String, usize> = BTreeMap::new();
            for e in manifest.executables.values().filter(|e| e.model == info.name) {
                let entry =
                    e.name.split_once('/').map(|(_, s)| s).unwrap_or(&e.name);
                *patterns.entry(collapse_entry(entry)).or_default() += 1;
            }
            let total: usize = patterns.values().sum();
            let dp_tag = if dp_capable.contains(&info.name.as_str()) {
                any_dp = true;
                " [dp]"
            } else {
                ""
            };
            let _ = writeln!(
                out,
                "  {} (kind {}, {} entries){}:",
                info.name, info.kind, total, dp_tag
            );
            for (pat, n) in patterns {
                if n == 1 {
                    let _ = writeln!(out, "    {pat}");
                } else {
                    let _ = writeln!(out, "    {pat}  x{n}");
                }
            }
        }
    }
    if any_dp {
        let _ = writeln!(
            out,
            "\n[dp] = runs under `flora train-dp` (Flora-compressed \
             data-parallel training; docs/DISTRIBUTED.md)"
        );
    }
    out
}

/// Collapse one executable name (model prefix stripped) to its step
/// pattern: any `_r<digits>` becomes `_r{N}` and a trailing
/// base-optimizer name becomes `{opt}`. Compressor-tagged entries
/// (`*_altlora`, `*_adarank`) keep their tag but collapse identically —
/// the tag is stripped, the flora-style pattern collapses, then the tag
/// is re-appended, so the grid grows the summary by one pattern per
/// compressor instead of one line per rank × optimizer.
fn collapse_entry(name: &str) -> String {
    for comp in CompressorKind::ALL {
        if comp == CompressorKind::Flora {
            continue;
        }
        let tag = format!("_{}", comp.name());
        if let Some(stripped) = name.strip_suffix(tag.as_str()) {
            return format!("{}{tag}", collapse_entry(stripped));
        }
    }
    let mut base = name.to_string();
    for opt in OptimizerKind::ALL {
        let suffix = format!("_{}", opt.name());
        if base.ends_with(&suffix) {
            base.truncate(base.len() - suffix.len());
            base.push_str("_{opt}");
            break;
        }
    }
    let b = base.as_bytes();
    let mut out = String::with_capacity(base.len());
    let mut i = 0;
    while i < b.len() {
        if b[i] == b'_' && i + 2 < b.len() && b[i + 1] == b'r' && b[i + 2].is_ascii_digit() {
            out.push_str("_r{N}");
            i += 2;
            while i < b.len() && b[i].is_ascii_digit() {
                i += 1;
            }
        } else {
            out.push(b[i] as char);
            i += 1;
        }
    }
    out
}

/// Build the native catalog: the manifest the coordinator consumes plus
/// the backend that executes it. Both come from one generator so the ABI
/// (names, input/output order, shapes) cannot drift between them.
pub fn catalog() -> (Manifest, NativeBackend) {
    let mut models = BTreeMap::new();
    let mut executables = BTreeMap::new();
    let mut execs = BTreeMap::new();

    for (model, vocab, seq_len) in MODELS {
        let mut fields = BTreeMap::new();
        fields.insert("vocab".to_string(), vocab as f64);
        fields.insert("seq_len".to_string(), seq_len as f64);
        fields.insert("d_model".to_string(), vocab as f64);
        fields.insert("n_layers".to_string(), 1.0);
        models.insert(
            model.to_string(),
            ModelInfo { name: model.to_string(), kind: "lm".into(), fields },
        );

        let mut reg = Registrar {
            executables: &mut executables,
            execs: &mut execs,
            model: model.to_string(),
            family: Family::Bigram { vocab },
        };
        let v = vocab;
        let s = seq_len;
        let b = SPEC_BATCH;
        let params = f32s("params/w", &[v, v]);
        let tokens = spec("batch/tokens", &[b, s], "int32");
        let mask = f32s("batch/mask", &[b, s]);
        let loss = f32s("loss", &[]);
        let lr = f32s("lr", &[]);
        let step_s = f32s("step", &[]);
        let seed = spec("seed", &[], "uint32");
        let acc_full = f32s("acc/w", &[v, v]);
        let mom_full = f32s("mom/w", &[v, v]);

        reg.add(
            format!("{model}/init"),
            Step::Init,
            vec![seed.clone()],
            vec![params.clone()],
        );
        reg.add(
            format!("{model}/eval"),
            Step::Eval,
            vec![params.clone(), tokens.clone(), mask.clone()],
            vec![loss.clone()],
        );
        reg.add(
            format!("{model}/greedy"),
            Step::Greedy,
            vec![
                params.clone(),
                tokens.clone(),
                spec("prompt_len", &[], "int32"),
            ],
            vec![spec("tokens", &[b, s], "int32")],
        );

        // Algorithm-1 micro steps accumulate only — no optimizer involved,
        // so one entry each regardless of the base optimizer.
        reg.add(
            format!("{model}/micro_naive"),
            Step::MicroNaive,
            vec![
                params.clone(),
                acc_full.clone(),
                tokens.clone(),
                mask.clone(),
                seed.clone(),
            ],
            vec![loss.clone(), acc_full.clone()],
        );
        for r in RANKS {
            if r > v {
                continue;
            }
            let acc = f32s("acc/w", &[v, r]);
            reg.add(
                format!("{model}/micro_flora_r{r}"),
                Step::MicroFlora { rank: r },
                vec![
                    params.clone(),
                    acc.clone(),
                    tokens.clone(),
                    mask.clone(),
                    seed.clone(),
                ],
                vec![loss.clone(), acc],
            );
        }

        // Update-bearing steps: one set per base optimizer, with that
        // optimizer's state tensors spliced into the ABI as `opt/{slot}/w`.
        for opt in OptimizerKind::ALL {
            let opt_specs: Vec<TensorSpec> = opt
                .build()
                .state_shapes(v, v)
                .iter()
                .map(|(slot, sh)| f32s(&format!("opt/{slot}/w"), &sh[..]))
                .collect();
            let o = opt.name();

            reg.add(
                format!("{model}/plain_step_{o}"),
                Step::Plain { opt },
                splice(
                    vec![params.clone()],
                    &opt_specs,
                    vec![tokens.clone(), mask.clone(), lr.clone(), step_s.clone()],
                ),
                splice(vec![loss.clone(), params.clone()], &opt_specs, vec![]),
            );
            reg.add(
                format!("{model}/update_naive_{o}"),
                Step::UpdateNaive { opt },
                splice(
                    vec![params.clone(), acc_full.clone()],
                    &opt_specs,
                    vec![lr.clone(), step_s.clone(), seed.clone(), f32s("tau", &[])],
                ),
                splice(vec![params.clone()], &opt_specs, vec![]),
            );
            reg.add(
                format!("{model}/mom_step_naive_{o}"),
                Step::MomNaive { opt },
                splice(
                    vec![params.clone(), mom_full.clone()],
                    &opt_specs,
                    vec![tokens.clone(), mask.clone(), lr.clone(), step_s.clone()],
                ),
                splice(
                    vec![loss.clone(), params.clone(), mom_full.clone()],
                    &opt_specs,
                    vec![],
                ),
            );

            for r in RANKS {
                if r > v {
                    continue;
                }
                let acc = f32s("acc/w", &[v, r]);
                let mom = f32s("mom/w", &[v, r]);
                reg.add(
                    format!("{model}/update_flora_r{r}_{o}"),
                    Step::UpdateFlora { rank: r, opt },
                    splice(
                        vec![params.clone(), acc],
                        &opt_specs,
                        vec![
                            lr.clone(),
                            step_s.clone(),
                            seed.clone(),
                            f32s("tau", &[]),
                        ],
                    ),
                    splice(vec![params.clone()], &opt_specs, vec![]),
                );
                let mom_inputs = splice(
                    vec![params.clone(), mom.clone()],
                    &opt_specs,
                    vec![
                        tokens.clone(),
                        mask.clone(),
                        lr.clone(),
                        step_s.clone(),
                        spec("seed_cur", &[], "uint32"),
                        spec("seed_next", &[], "uint32"),
                        f32s("resample", &[]),
                    ],
                );
                let mom_outputs = splice(
                    vec![loss.clone(), params.clone(), mom.clone()],
                    &opt_specs,
                    vec![],
                );
                reg.add(
                    format!("{model}/mom_step_flora_r{r}_{o}"),
                    Step::MomFlora { rank: r, transfer: true, opt },
                    mom_inputs.clone(),
                    mom_outputs.clone(),
                );
                reg.add(
                    format!("{model}/mom_step_flora_notransfer_r{r}_{o}"),
                    Step::MomFlora { rank: r, transfer: false, opt },
                    mom_inputs,
                    mom_outputs,
                );
            }
        }

        // GaLore baseline: Adam-in-subspace with a stored projection and
        // κ-interval refresh; its moments are method state, not opt state.
        for r in RANKS {
            if r > v {
                continue;
            }
            reg.add(
                format!("{model}/galore_step_r{r}"),
                Step::GaloreStep { rank: r },
                vec![
                    params.clone(),
                    f32s("m/w", &[v, r]),
                    f32s("proj/w", &[r, v]),
                    f32s("v/w", &[v, r]),
                    tokens.clone(),
                    mask.clone(),
                    lr.clone(),
                    step_s.clone(),
                    seed.clone(),
                    f32s("refresh", &[]),
                ],
                vec![
                    loss.clone(),
                    params.clone(),
                    f32s("m/w", &[v, r]),
                    f32s("proj/w", &[r, v]),
                    f32s("v/w", &[v, r]),
                ],
            );
        }
    }

    for (name, cfg) in TransformerConfig::catalog_grid() {
        register_lm_family(&mut models, &mut executables, &mut execs, name, cfg);
    }
    for (name, cfg) in VitConfig::catalog_grid() {
        register_vit_family(&mut models, &mut executables, &mut execs, name, cfg);
    }

    let families: Vec<String> = models.keys().cloned().collect();
    let manifest =
        Manifest { dir: PathBuf::from("native"), executables, models };
    (manifest, NativeBackend { execs, families })
}

fn spec(name: &str, shape: &[usize], dtype: &str) -> TensorSpec {
    TensorSpec {
        name: name.to_string(),
        shape: shape.to_vec(),
        dtype: dtype.to_string(),
    }
}

fn f32s(name: &str, shape: &[usize]) -> TensorSpec {
    spec(name, shape, "float32")
}

/// `head ++ mid ++ tail` — splices optimizer state specs into an ABI list.
fn splice(
    mut head: Vec<TensorSpec>,
    mid: &[TensorSpec],
    tail: Vec<TensorSpec>,
) -> Vec<TensorSpec> {
    head.extend(mid.iter().cloned());
    head.extend(tail);
    head
}

/// Per-family catalog builder: closes over the manifest/executor maps
/// and a family's fixed arguments (model name + [`Family`]), so one
/// catalog entry is one `add(...)` call — the closure that replaced the
/// ~35 open-coded `register(&mut executables, &mut execs, model, &fam,
/// ...)` sites (PR-3 review item).
struct Registrar<'a> {
    executables: &'a mut BTreeMap<String, ExecutableInfo>,
    execs: &'a mut BTreeMap<String, Rc<NativeExec>>,
    model: String,
    family: Family,
}

impl Registrar<'_> {
    fn add(
        &mut self,
        name: String,
        step: Step,
        inputs: Vec<TensorSpec>,
        outputs: Vec<TensorSpec>,
    ) {
        self.executables.insert(
            name.clone(),
            ExecutableInfo {
                name: name.clone(),
                file: PathBuf::from("native"),
                model: self.model.clone(),
                inputs: inputs.clone(),
                outputs,
            },
        );
        self.execs.insert(
            name.clone(),
            Rc::new(NativeExec {
                name,
                family: self.family.clone(),
                step,
                inputs,
            }),
        );
    }
}

// ---------------------------------------------------------------------
// transformer-family catalog generation
// ---------------------------------------------------------------------

type Shapes = [(String, [usize; 2])];

/// `{prefix}/{name}` specs for a whole parameter set, in ABI order.
fn set_specs(prefix: &str, shapes: &Shapes) -> Vec<TensorSpec> {
    shapes
        .iter()
        .map(|(n, s)| f32s(&format!("{prefix}/{n}"), &s[..]))
        .collect()
}

/// `opt/{param}/{slot}` specs for every parameter, grouped per parameter
/// in ABI order — the multi-matrix generalization of the bigram's
/// `opt/{slot}/w`.
fn opt_specs(shapes: &Shapes, opt: OptimizerKind) -> Vec<TensorSpec> {
    let o = opt.build();
    let mut out = Vec::new();
    for (name, sh) in shapes {
        for (slot, ss) in o.state_shapes(sh[0], sh[1]) {
            out.push(f32s(&format!("opt/{name}/{slot}"), &ss[..]));
        }
    }
    out
}

/// `{prefix}/{param}` method-state specs: compressed `[n, r]` for
/// projectable parameters when a rank is given (the FLORA treatment),
/// full-size otherwise (the paper's naive procedure / naive baselines).
fn method_specs(prefix: &str, shapes: &Shapes, rank: Option<usize>) -> Vec<TensorSpec> {
    shapes
        .iter()
        .map(|(name, sh)| {
            let shape = match rank {
                Some(r) if is_projectable(name) => [sh[0], r],
                _ => *sh,
            };
            f32s(&format!("{prefix}/{name}"), &shape[..])
        })
        .collect()
}

/// AltLoRA left-sketch specs `ralt/{param}` — `[r, m]` for projectable
/// parameters ONLY: the naive-procedure parameters accumulate full-size
/// in `acc/` and need no second sketch.
fn ralt_specs(shapes: &Shapes, rank: usize) -> Vec<TensorSpec> {
    shapes
        .iter()
        .filter(|(name, _)| is_projectable(name))
        .map(|(name, sh)| f32s(&format!("ralt/{name}"), &[rank, sh[1]]))
        .collect()
}

/// GaLore state specs, per parameter: subspace moments `m`/`v` plus the
/// STORED projection `proj` on projectable parameters, full-space Adam
/// moments on the rest.
fn galore_specs(shapes: &Shapes, rank: usize) -> Vec<TensorSpec> {
    let mut out = Vec::new();
    for (name, sh) in shapes {
        if is_projectable(name) {
            out.push(f32s(&format!("m/{name}"), &[sh[0], rank]));
            out.push(f32s(&format!("proj/{name}"), &[rank, sh[1]]));
            out.push(f32s(&format!("v/{name}"), &[sh[0], rank]));
        } else {
            out.push(f32s(&format!("m/{name}"), &[sh[0], sh[1]]));
            out.push(f32s(&format!("v/{name}"), &[sh[0], sh[1]]));
        }
    }
    out
}

/// One `lora-*` transformer catalog family: init/eval/greedy, plain
/// steps, Algorithm-1 micro/update, Algorithm-2 momentum (± transfer),
/// the LoRA adapter baseline and GaLore — each update-bearing step over
/// every base optimizer, exactly the surface the bigram models expose.
/// Called once per `TransformerConfig::catalog_grid()` size.
fn register_lm_family(
    models: &mut BTreeMap<String, ModelInfo>,
    executables: &mut BTreeMap<String, ExecutableInfo>,
    execs: &mut BTreeMap<String, Rc<NativeExec>>,
    model: &str,
    cfg: TransformerConfig,
) {
    let mut fields = BTreeMap::new();
    fields.insert("vocab".to_string(), cfg.vocab as f64);
    fields.insert("seq_len".to_string(), cfg.seq_len as f64);
    fields.insert("d_model".to_string(), cfg.dims.d_model as f64);
    fields.insert("n_layers".to_string(), cfg.dims.n_layers as f64);
    fields.insert("n_heads".to_string(), cfg.dims.n_heads as f64);
    fields.insert("d_ff".to_string(), cfg.dims.d_ff as f64);
    models.insert(
        model.to_string(),
        ModelInfo { name: model.to_string(), kind: "lm".into(), fields },
    );

    let mut reg = Registrar {
        executables,
        execs,
        model: model.to_string(),
        family: Family::Lm(cfg),
    };
    let shapes = cfg.param_shapes();
    let pspecs = set_specs("params", &shapes);
    let b = SPEC_BATCH;
    let s = cfg.seq_len;
    let tokens = spec("batch/tokens", &[b, s], "int32");
    let mask = f32s("batch/mask", &[b, s]);
    let loss = f32s("loss", &[]);
    let lr = f32s("lr", &[]);
    let step_s = f32s("step", &[]);
    let seed = spec("seed", &[], "uint32");
    let tau = f32s("tau", &[]);
    let acc_naive = method_specs("acc", &shapes, None);
    let mom_naive = method_specs("mom", &shapes, None);

    reg.add(
        format!("{model}/init"),
        Step::TfInit,
        vec![seed.clone()],
        pspecs.clone(),
    );
    reg.add(
        format!("{model}/eval"),
        Step::TfEval,
        splice(pspecs.clone(), &[], vec![tokens.clone(), mask.clone()]),
        vec![loss.clone()],
    );
    reg.add(
        format!("{model}/greedy"),
        Step::TfGreedy,
        splice(
            pspecs.clone(),
            &[],
            vec![tokens.clone(), spec("prompt_len", &[], "int32")],
        ),
        vec![spec("tokens", &[b, s], "int32")],
    );
    reg.add(
        format!("{model}/micro_naive"),
        Step::TfMicroNaive,
        splice(pspecs.clone(), &acc_naive, vec![tokens.clone(), mask.clone()]),
        splice(vec![loss.clone()], &acc_naive, vec![]),
    );
    for r in TF_RANKS {
        let acc = method_specs("acc", &shapes, Some(r));
        reg.add(
            format!("{model}/micro_flora_r{r}"),
            Step::TfMicroFlora { rank: r },
            splice(
                splice(pspecs.clone(), &acc, vec![]),
                &[],
                vec![tokens.clone(), mask.clone(), seed.clone()],
            ),
            splice(vec![loss.clone()], &acc, vec![]),
        );
        // AltLoRA micro: both sketches accumulate under the one cycle seed
        let ralt = ralt_specs(&shapes, r);
        reg.add(
            format!("{model}/micro_r{r}_altlora"),
            Step::TfMicroAlt { rank: r },
            splice(
                splice(splice(pspecs.clone(), &acc, vec![]), &ralt, vec![]),
                &[],
                vec![tokens.clone(), mask.clone(), seed.clone()],
            ),
            splice(splice(vec![loss.clone()], &acc, vec![]), &ralt, vec![]),
        );
    }

    for opt in OptimizerKind::ALL {
        let ospecs = opt_specs(&shapes, opt);
        let o = opt.name();
        reg.add(
            format!("{model}/plain_step_{o}"),
            Step::TfPlain { opt },
            splice(
                splice(pspecs.clone(), &ospecs, vec![]),
                &[],
                vec![tokens.clone(), mask.clone(), lr.clone(), step_s.clone()],
            ),
            splice(splice(vec![loss.clone()], &pspecs, vec![]), &ospecs, vec![]),
        );
        reg.add(
            format!("{model}/update_naive_{o}"),
            Step::TfUpdateNaive { opt },
            splice(
                splice(pspecs.clone(), &ospecs, vec![]),
                &acc_naive,
                vec![lr.clone(), step_s.clone(), tau.clone()],
            ),
            splice(pspecs.clone(), &ospecs, vec![]),
        );
        reg.add(
            format!("{model}/mom_step_naive_{o}"),
            Step::TfMomNaive { opt },
            splice(
                splice(pspecs.clone(), &ospecs, vec![]),
                &mom_naive,
                vec![tokens.clone(), mask.clone(), lr.clone(), step_s.clone()],
            ),
            splice(
                splice(splice(vec![loss.clone()], &pspecs, vec![]), &ospecs, vec![]),
                &mom_naive,
                vec![],
            ),
        );
        for r in TF_RANKS {
            let acc = method_specs("acc", &shapes, Some(r));
            reg.add(
                format!("{model}/update_flora_r{r}_{o}"),
                Step::TfUpdateFlora { rank: r, opt },
                splice(
                    splice(pspecs.clone(), &ospecs, vec![]),
                    &acc,
                    vec![lr.clone(), step_s.clone(), seed.clone(), tau.clone()],
                ),
                splice(pspecs.clone(), &ospecs, vec![]),
            );
            let mom = method_specs("mom", &shapes, Some(r));
            let mom_in = splice(
                splice(pspecs.clone(), &ospecs, vec![]),
                &mom,
                vec![
                    tokens.clone(),
                    mask.clone(),
                    lr.clone(),
                    step_s.clone(),
                    spec("seed_cur", &[], "uint32"),
                    spec("seed_next", &[], "uint32"),
                    f32s("resample", &[]),
                ],
            );
            let mom_out = splice(
                splice(splice(vec![loss.clone()], &pspecs, vec![]), &ospecs, vec![]),
                &mom,
                vec![],
            );
            reg.add(
                format!("{model}/mom_step_flora_r{r}_{o}"),
                Step::TfMomFlora { rank: r, transfer: true, opt },
                mom_in.clone(),
                mom_out.clone(),
            );
            reg.add(
                format!("{model}/mom_step_flora_notransfer_r{r}_{o}"),
                Step::TfMomFlora { rank: r, transfer: false, opt },
                mom_in,
                mom_out,
            );
            // adaptive-rank compressor grid: AltLoRA cycle-end update
            // (dual sketches in, alternating-projection estimate out) and
            // the AdaRank ranked momentum step, whose active ranks arrive
            // as rank_cur/rank_next scalars from the trainer's schedule.
            let ralt = ralt_specs(&shapes, r);
            reg.add(
                format!("{model}/update_r{r}_{o}_altlora"),
                Step::TfUpdateAlt { rank: r, opt },
                splice(
                    splice(
                        splice(splice(pspecs.clone(), &ospecs, vec![]), &acc, vec![]),
                        &ralt,
                        vec![],
                    ),
                    &[],
                    vec![lr.clone(), step_s.clone(), seed.clone(), tau.clone()],
                ),
                splice(pspecs.clone(), &ospecs, vec![]),
            );
            reg.add(
                format!("{model}/mom_step_r{r}_{o}_adarank"),
                Step::TfMomAdaRank { rank: r, opt },
                splice(
                    splice(pspecs.clone(), &ospecs, vec![]),
                    &mom,
                    vec![
                        tokens.clone(),
                        mask.clone(),
                        lr.clone(),
                        step_s.clone(),
                        spec("seed_cur", &[], "uint32"),
                        spec("seed_next", &[], "uint32"),
                        f32s("resample", &[]),
                        f32s("rank_cur", &[]),
                        f32s("rank_next", &[]),
                    ],
                ),
                splice(
                    splice(splice(vec![loss.clone()], &pspecs, vec![]), &ospecs, vec![]),
                    &mom,
                    vec![],
                ),
            );
        }
    }

    // LoRA adapter baseline + GaLore, per rank
    for r in TF_RANKS {
        let adapter = LoraAdapter::new(shapes.clone(), r);
        let tshapes = adapter.trainable_shapes();
        let tspecs = set_specs("train", &tshapes);
        let acc_t = method_specs("acc", &tshapes, None);
        reg.add(
            format!("{model}/lora_r{r}_init"),
            Step::LoraInit { rank: r },
            splice(pspecs.clone(), &[], vec![seed.clone()]),
            tspecs.clone(),
        );
        reg.add(
            format!("{model}/lora_r{r}_eval"),
            Step::LoraEval { rank: r },
            splice(
                splice(pspecs.clone(), &tspecs, vec![]),
                &[],
                vec![tokens.clone(), mask.clone()],
            ),
            vec![loss.clone()],
        );
        reg.add(
            format!("{model}/lora_r{r}_greedy"),
            Step::LoraGreedy { rank: r },
            splice(
                splice(pspecs.clone(), &tspecs, vec![]),
                &[],
                vec![tokens.clone(), spec("prompt_len", &[], "int32")],
            ),
            vec![spec("tokens", &[b, s], "int32")],
        );
        reg.add(
            format!("{model}/lora_r{r}_micro"),
            Step::LoraMicro { rank: r },
            splice(
                splice(pspecs.clone(), &tspecs, vec![]),
                &acc_t,
                vec![tokens.clone(), mask.clone()],
            ),
            splice(vec![loss.clone()], &acc_t, vec![]),
        );
        for opt in OptimizerKind::ALL {
            let o = opt.name();
            let ospecs_t = opt_specs(&tshapes, opt);
            reg.add(
                format!("{model}/lora_r{r}_update_{o}"),
                Step::LoraUpdate { rank: r, opt },
                splice(
                    splice(tspecs.clone(), &ospecs_t, vec![]),
                    &acc_t,
                    vec![lr.clone(), step_s.clone(), tau.clone()],
                ),
                splice(tspecs.clone(), &ospecs_t, vec![]),
            );
            let mom_t = method_specs("mom", &tshapes, None);
            reg.add(
                format!("{model}/lora_r{r}_mom_step_{o}"),
                Step::LoraMom { rank: r, opt },
                splice(
                    splice(
                        splice(pspecs.clone(), &tspecs, vec![]),
                        &ospecs_t,
                        vec![],
                    ),
                    &mom_t,
                    vec![tokens.clone(), mask.clone(), lr.clone(), step_s.clone()],
                ),
                splice(
                    splice(
                        splice(vec![loss.clone()], &tspecs, vec![]),
                        &ospecs_t,
                        vec![],
                    ),
                    &mom_t,
                    vec![],
                ),
            );
        }
        let gspecs = galore_specs(&shapes, r);
        reg.add(
            format!("{model}/galore_step_r{r}"),
            Step::TfGalore { rank: r },
            splice(
                splice(pspecs.clone(), &gspecs, vec![]),
                &[],
                vec![
                    tokens.clone(),
                    mask.clone(),
                    lr.clone(),
                    step_s.clone(),
                    seed.clone(),
                    f32s("refresh", &[]),
                ],
            ),
            splice(splice(vec![loss.clone()], &pspecs, vec![]), &gspecs, vec![]),
        );
    }
}

/// One `vit-*` catalog family: Table-5 training steps (plain per
/// optimizer and FLORA Algorithm-2 momentum per rank × optimizer), plus
/// init and a loss+preds eval. Called once per
/// `VitConfig::catalog_grid()` size.
fn register_vit_family(
    models: &mut BTreeMap<String, ModelInfo>,
    executables: &mut BTreeMap<String, ExecutableInfo>,
    execs: &mut BTreeMap<String, Rc<NativeExec>>,
    model: &str,
    cfg: VitConfig,
) {
    let mut fields = BTreeMap::new();
    fields.insert("image_size".to_string(), cfg.image_size as f64);
    fields.insert("patch_size".to_string(), cfg.patch_size as f64);
    fields.insert("channels".to_string(), cfg.channels as f64);
    fields.insert("n_classes".to_string(), cfg.n_classes as f64);
    fields.insert("d_model".to_string(), cfg.dims.d_model as f64);
    fields.insert("n_layers".to_string(), cfg.dims.n_layers as f64);
    fields.insert("n_heads".to_string(), cfg.dims.n_heads as f64);
    fields.insert("d_ff".to_string(), cfg.dims.d_ff as f64);
    models.insert(
        model.to_string(),
        ModelInfo { name: model.to_string(), kind: "vit".into(), fields },
    );

    let mut reg = Registrar {
        executables,
        execs,
        model: model.to_string(),
        family: Family::Vit(cfg),
    };
    let shapes = cfg.param_shapes();
    let pspecs = set_specs("params", &shapes);
    let b = SPEC_BATCH;
    let side = cfg.image_size;
    let images = f32s("batch/images", &[b, side, side, cfg.channels]);
    let labels = spec("batch/labels", &[b], "int32");
    let loss = f32s("loss", &[]);
    let lr = f32s("lr", &[]);
    let step_s = f32s("step", &[]);

    reg.add(
        format!("{model}/init"),
        Step::VitInit,
        vec![spec("seed", &[], "uint32")],
        pspecs.clone(),
    );
    reg.add(
        format!("{model}/eval"),
        Step::VitEval,
        splice(pspecs.clone(), &[], vec![images.clone(), labels.clone()]),
        vec![loss.clone(), spec("preds", &[b], "int32")],
    );
    for opt in OptimizerKind::ALL {
        let o = opt.name();
        let ospecs = opt_specs(&shapes, opt);
        reg.add(
            format!("{model}/step_{o}"),
            Step::VitPlain { opt },
            splice(
                splice(pspecs.clone(), &ospecs, vec![]),
                &[],
                vec![images.clone(), labels.clone(), lr.clone(), step_s.clone()],
            ),
            splice(splice(vec![loss.clone()], &pspecs, vec![]), &ospecs, vec![]),
        );
        for r in TF_RANKS {
            let mom = method_specs("mom", &shapes, Some(r));
            reg.add(
                format!("{model}/step_flora_r{r}_{o}"),
                Step::VitMomFlora { rank: r, opt },
                splice(
                    splice(splice(pspecs.clone(), &ospecs, vec![]), &mom, vec![]),
                    &[],
                    vec![
                        images.clone(),
                        labels.clone(),
                        spec("seed_cur", &[], "uint32"),
                        spec("seed_next", &[], "uint32"),
                        f32s("resample", &[]),
                        lr.clone(),
                        step_s.clone(),
                    ],
                ),
                splice(
                    splice(
                        splice(vec![loss.clone()], &pspecs, vec![]),
                        &ospecs,
                        vec![],
                    ),
                    &mom,
                    vec![],
                ),
            );
            // adaptive-rank grid: the fused τ=1 AltLoRA step (per-step
            // seed derived from the cycle seed, no persistent method
            // state) and the AdaRank ranked momentum step.
            reg.add(
                format!("{model}/step_r{r}_{o}_altlora"),
                Step::VitAltStep { rank: r, opt },
                splice(
                    splice(pspecs.clone(), &ospecs, vec![]),
                    &[],
                    vec![
                        images.clone(),
                        labels.clone(),
                        spec("seed_cur", &[], "uint32"),
                        lr.clone(),
                        step_s.clone(),
                    ],
                ),
                splice(
                    splice(vec![loss.clone()], &pspecs, vec![]),
                    &ospecs,
                    vec![],
                ),
            );
            reg.add(
                format!("{model}/step_r{r}_{o}_adarank"),
                Step::VitAdaRank { rank: r, opt },
                splice(
                    splice(splice(pspecs.clone(), &ospecs, vec![]), &mom, vec![]),
                    &[],
                    vec![
                        images.clone(),
                        labels.clone(),
                        spec("seed_cur", &[], "uint32"),
                        spec("seed_next", &[], "uint32"),
                        f32s("resample", &[]),
                        f32s("rank_cur", &[]),
                        f32s("rank_next", &[]),
                        lr.clone(),
                        step_s.clone(),
                    ],
                ),
                splice(
                    splice(
                        splice(vec![loss.clone()], &pspecs, vec![]),
                        &ospecs,
                        vec![],
                    ),
                    &mom,
                    vec![],
                ),
            );
        }
    }
}

// ---------------------------------------------------------------------
// execution
// ---------------------------------------------------------------------

/// Borrowed view of an LM batch (tokens + loss mask).
struct BatchRef<'a> {
    tokens: &'a [i32],
    mask: &'a [f32],
    rows: usize,
    seq: usize,
}

fn batch_of<'a>(
    tokens: &'a Tensor,
    mask: &'a Tensor,
    ctx: &str,
) -> Result<BatchRef<'a>, String> {
    let (tshape, tdata) = match tokens {
        Tensor::I32 { shape, data } if shape.len() == 2 => (shape, data),
        _ => return Err(format!("{ctx}: batch/tokens must be 2-D int32")),
    };
    let mdata = mask.as_f32().map_err(|e| format!("{ctx}: batch/mask: {e}"))?;
    if mdata.len() != tdata.len() {
        return Err(format!("{ctx}: mask/tokens length mismatch"));
    }
    Ok(BatchRef {
        tokens: tdata,
        mask: mdata,
        rows: tshape[0],
        seq: tshape[1],
    })
}

fn matrix_of(t: &Tensor, ctx: &str) -> Result<Matrix, String> {
    match t {
        Tensor::F32 { shape, data } if shape.len() == 2 => {
            Ok(Matrix::from_vec(shape[0], shape[1], data.clone()))
        }
        other => Err(format!(
            "{ctx}: expected 2-D float32 tensor, got {:?} {}",
            other.shape(),
            other.dtype()
        )),
    }
}

fn tensor_of(m: Matrix) -> Tensor {
    Tensor::F32 { shape: vec![m.rows, m.cols], data: m.data }
}

/// Name-routed view of one invocation's inputs — the executor-side mirror
/// of the coordinator's `StepIo`, so neither side depends on positions.
struct Inputs<'a> {
    specs: &'a [TensorSpec],
    vals: &'a [Tensor],
    ctx: &'a str,
}

impl<'a> Inputs<'a> {
    fn get(&self, name: &str) -> Result<&'a Tensor, String> {
        self.specs
            .iter()
            .position(|s| s.name == name)
            .and_then(|i| self.vals.get(i))
            .ok_or_else(|| format!("{}: missing input {name:?}", self.ctx))
    }

    fn matrix(&self, name: &str) -> Result<Matrix, String> {
        matrix_of(self.get(name)?, self.ctx)
    }

    fn num(&self, name: &str) -> Result<f32, String> {
        self.get(name)?
            .first_f32()
            .map_err(|e| format!("{}: {name}: {e}", self.ctx))
    }

    fn useed(&self, name: &str) -> Result<u64, String> {
        self.get(name)?
            .first_u32()
            .map(|v| v as u64)
            .map_err(|e| format!("{}: {name}: {e}", self.ctx))
    }

    fn batch(&self) -> Result<BatchRef<'a>, String> {
        batch_of(self.get("batch/tokens")?, self.get("batch/mask")?, self.ctx)
    }

    /// All `opt/...` state tensors in declared (state_shapes) order.
    fn opt_state(&self) -> Result<Vec<Matrix>, String> {
        self.specs
            .iter()
            .zip(self.vals.iter())
            .filter(|(s, _)| s.name.starts_with("opt/"))
            .map(|(s, v)| {
                matrix_of(v, self.ctx)
                    .map_err(|e| format!("{} ({}): {e}", self.ctx, s.name))
            })
            .collect()
    }
}

/// Masked next-token cross-entropy of the bigram logit table, plus
/// (optionally) its gradient dL/dW. Both are normalized by the total mask
/// weight, mirroring the AOT step functions.
fn loss_and_grad(
    w: &Matrix,
    batch: &BatchRef<'_>,
    want_grad: bool,
    ctx: &str,
) -> Result<(f32, Matrix), String> {
    let v = w.cols;
    // eval paths (want_grad=false) skip the [v, v] gradient allocation —
    // at lm-base scale that is 1 MiB zeroed per eval batch otherwise
    let mut grad = if want_grad {
        Matrix::zeros(w.rows, w.cols)
    } else {
        Matrix::zeros(0, 0)
    };
    let mut total_w = 0.0f64;
    let mut total_loss = 0.0f64;
    let mut expd = vec![0.0f32; v];
    for row in 0..batch.rows {
        for i in 1..batch.seq {
            let wt = batch.mask[row * batch.seq + i];
            if wt <= 0.0 {
                continue;
            }
            let prev = batch.tokens[row * batch.seq + i - 1];
            let tgt = batch.tokens[row * batch.seq + i];
            if prev < 0 || prev as usize >= v || tgt < 0 || tgt as usize >= v
            {
                return Err(format!(
                    "{ctx}: token id out of range for vocab {v} \
                     (prev={prev} tgt={tgt})"
                ));
            }
            let (prev, tgt) = (prev as usize, tgt as usize);
            let logits = w.row(prev);
            let mx =
                logits.iter().fold(f32::NEG_INFINITY, |m, &x| m.max(x));
            let mut denom = 0.0f32;
            for (e, &x) in expd.iter_mut().zip(logits.iter()) {
                *e = (x - mx).exp();
                denom += *e;
            }
            total_loss +=
                wt as f64 * (denom.ln() + mx - logits[tgt]) as f64;
            total_w += wt as f64;
            if want_grad {
                for j in 0..v {
                    let p = expd[j] / denom;
                    let delta = if j == tgt { p - 1.0 } else { p };
                    *grad.at_mut(prev, j) += wt * delta;
                }
            }
        }
    }
    if total_w <= 0.0 {
        return Ok((0.0, grad));
    }
    let inv = (1.0 / total_w) as f32;
    if want_grad {
        for x in grad.data.iter_mut() {
            *x *= inv;
        }
    }
    Ok(((total_loss / total_w) as f32, grad))
}

/// `[head..., opt_state...]` — the standard output layout of an
/// update-bearing step.
fn outputs_with_state(head: Vec<Tensor>, state: Vec<Matrix>) -> Vec<Tensor> {
    let mut out = head;
    out.extend(state.into_iter().map(tensor_of));
    out
}

// ---------------------------------------------------------------------
// transformer-family execution helpers
// ---------------------------------------------------------------------

/// Read a whole named parameter set (`{prefix}/{name}`) from step inputs.
fn read_set(
    ins: &Inputs<'_>,
    shapes: &Shapes,
    prefix: &str,
) -> Result<ParamSet, String> {
    let mut out = ParamSet::new();
    for (name, _) in shapes {
        out.insert(name.clone(), ins.matrix(&format!("{prefix}/{name}"))?);
    }
    Ok(out)
}

/// Emit a parameter set as output tensors in ABI (sorted-name) order.
fn set_tensors(params: ParamSet) -> Vec<Tensor> {
    params.into_values().map(tensor_of).collect()
}

/// Greedy-decode inputs shared by every model family: the 2-D int32
/// token grid (cloned for in-place decoding) plus the prompt length,
/// clamped to >= 1 ONCE here at the ABI boundary (position 0 has no
/// predecessor to condition on; the model's `greedy` clamps again only
/// for its own direct callers). Returns `(rows, seq, tokens, plen)`.
fn greedy_tokens(
    ins: &Inputs<'_>,
    ctx: &str,
) -> Result<(usize, usize, Vec<i32>, usize), String> {
    let (rows, s, toks) = match ins.get("batch/tokens")? {
        Tensor::I32 { shape, data } if shape.len() == 2 => {
            (shape[0], shape[1], data.clone())
        }
        _ => return Err(format!("{ctx}: batch/tokens must be 2-D int32")),
    };
    let plen = ins
        .get("prompt_len")?
        .first_i32()
        .map_err(|e| format!("{ctx}: prompt_len: {e}"))?
        .max(1) as usize;
    Ok((rows, s, toks, plen))
}

/// ViT image/label batch view: dtype extraction only — shape validation
/// is owned by `VitConfig::check_batch`, which every loss/preds entry
/// point runs.
fn vit_batch<'a>(
    ins: &Inputs<'a>,
    ctx: &str,
) -> Result<(&'a [f32], &'a [i32]), String> {
    let images = ins
        .get("batch/images")?
        .as_f32()
        .map_err(|e| format!("{ctx}: batch/images: {e}"))?;
    let labels = ins
        .get("batch/labels")?
        .as_i32()
        .map_err(|e| format!("{ctx}: batch/labels: {e}"))?;
    Ok((images, labels))
}

/// Per-parameter base-optimizer update over a whole set: reads each
/// parameter's `opt/{name}/{slot}` state, applies the update with that
/// parameter's effective gradient, and returns the new state tensors in
/// catalog spec order.
fn opt_update_set(
    opt: OptimizerKind,
    params: &mut ParamSet,
    eff: &ParamSet,
    ins: &Inputs<'_>,
    lr: f32,
    step: f32,
) -> Result<Vec<Tensor>, String> {
    let o = opt.build();
    let names: Vec<String> = params.keys().cloned().collect();
    let mut out = Vec::new();
    for name in names {
        let w = params.get_mut(&name).expect("name from keys");
        let g = eff
            .get(&name)
            .ok_or_else(|| format!("missing gradient for {name}"))?;
        let mut st: Vec<Matrix> = o
            .state_shapes(w.rows, w.cols)
            .iter()
            .map(|(slot, _)| ins.matrix(&format!("opt/{name}/{slot}")))
            .collect::<Result<_, _>>()?;
        o.update(w, g, &mut st, lr, step)?;
        out.extend(st.into_iter().map(tensor_of));
    }
    Ok(out)
}

/// Algorithm-1 micro accumulation over a whole gradient set: compressed
/// `C += G Aᵀ` with per-parameter seeds on projectable parameters (rank
/// Some), plain `acc += G` otherwise. Returns the new accumulators in
/// spec order.
fn accumulate_set(
    rank: Option<usize>,
    grads: &ParamSet,
    ins: &Inputs<'_>,
    seed: u64,
) -> Result<Vec<Tensor>, String> {
    let comp = rank.map(|r| FloraCompressor::new(crate::opt::Sgd, r));
    let mut out = Vec::new();
    for (idx, (name, g)) in grads.iter().enumerate() {
        let mut acc = ins.matrix(&format!("acc/{name}"))?;
        match &comp {
            Some(comp) if is_projectable(name) => {
                comp.accumulate(&mut acc, g, rp::param_seed(seed, idx));
            }
            _ => acc.add_scaled_inplace(g, 1.0),
        }
        out.push(tensor_of(acc));
    }
    Ok(out)
}

/// Algorithm-1 cycle end over a whole set: decompress each projectable
/// accumulator with ITS parameter's seed (rank Some) or take the naive
/// mean, then run the base optimizer. Returns the new opt-state tensors.
#[allow(clippy::too_many_arguments)]
fn apply_accumulated_set(
    opt: OptimizerKind,
    rank: Option<usize>,
    params: &mut ParamSet,
    ins: &Inputs<'_>,
    seed: u64,
    tau: f32,
    lr: f32,
    step: f32,
) -> Result<Vec<Tensor>, String> {
    let o = opt.build();
    let comp = rank.map(|r| FloraCompressor::new(opt.build(), r));
    let names: Vec<String> = params.keys().cloned().collect();
    let mut out = Vec::new();
    for (idx, name) in names.iter().enumerate() {
        let w = params.get_mut(name).expect("name from keys");
        let acc = ins.matrix(&format!("acc/{name}"))?;
        let mut st: Vec<Matrix> = o
            .state_shapes(w.rows, w.cols)
            .iter()
            .map(|(slot, _)| ins.matrix(&format!("opt/{name}/{slot}")))
            .collect::<Result<_, _>>()?;
        match &comp {
            Some(comp) if is_projectable(name) => {
                comp.apply_accumulated(
                    w,
                    &acc,
                    &mut st,
                    rp::param_seed(seed, idx),
                    tau,
                    lr,
                    step,
                )?;
            }
            _ => {
                let ghat = acc.scale(1.0 / tau.max(1.0));
                o.update(w, &ghat, &mut st, lr, step)?;
            }
        }
        out.extend(st.into_iter().map(tensor_of));
    }
    Ok(out)
}

/// One Algorithm-2 (or naive-EMA) momentum step over a whole parameter
/// set. With a rank, projectable parameters keep their EMA in the
/// compressed subspace, deriving per-parameter seeds from the tick's
/// cycle seeds; everything else (and rank None) is a full-space EMA fed
/// to the base optimizer. Returns (opt-state, momentum) output tensors.
#[allow(clippy::too_many_arguments)]
fn momentum_step_set(
    opt: OptimizerKind,
    rank: Option<usize>,
    transfer: bool,
    params: &mut ParamSet,
    grads: &ParamSet,
    ins: &Inputs<'_>,
    tick: Option<(u64, u64, bool)>,
    lr: f32,
    step: f32,
) -> Result<(Vec<Tensor>, Vec<Tensor>), String> {
    let o = opt.build();
    let comp = rank.map(|r| FloraCompressor::new(opt.build(), r));
    let names: Vec<String> = params.keys().cloned().collect();
    let mut opt_out = Vec::new();
    let mut mom_out = Vec::new();
    for (idx, name) in names.iter().enumerate() {
        let w = params.get_mut(name).expect("name from keys");
        let g = grads
            .get(name)
            .ok_or_else(|| format!("missing gradient for {name}"))?;
        let mut mom = ins.matrix(&format!("mom/{name}"))?;
        let mut st: Vec<Matrix> = o
            .state_shapes(w.rows, w.cols)
            .iter()
            .map(|(slot, _)| ins.matrix(&format!("opt/{name}/{slot}")))
            .collect::<Result<_, _>>()?;
        match &comp {
            Some(comp) if is_projectable(name) => {
                let (seed_cur, seed_next, resample) =
                    tick.ok_or("flora momentum step without subspace seeds")?;
                let t = SubspaceTick {
                    seed_cur: rp::param_seed(seed_cur, idx),
                    seed_next: rp::param_seed(seed_next, idx),
                    resample,
                    transfer,
                };
                comp.momentum_step(w, &mut mom, &mut st, g, t, lr, step)?;
            }
            _ => {
                let mut next = mom.scale(MOMENTUM_BETA);
                next.add_scaled_inplace(g, 1.0 - MOMENTUM_BETA);
                o.update(w, &next, &mut st, lr, step)?;
                mom = next;
            }
        }
        opt_out.extend(st.into_iter().map(tensor_of));
        mom_out.push(tensor_of(mom));
    }
    Ok((opt_out, mom_out))
}

/// AltLoRA micro accumulation over a whole gradient set: dual sketches
/// (`acc += G Aᵀ`, `ralt += P G`) on projectable parameters, plain
/// `acc += G` (no left sketch) on the naive-procedure rest. Returns the
/// `(acc, ralt)` tensors, each group in spec order.
fn alt_accumulate_set(
    rank: usize,
    grads: &ParamSet,
    ins: &Inputs<'_>,
    seed: u64,
) -> Result<(Vec<Tensor>, Vec<Tensor>), String> {
    let comp = AltLoraCompressor::new(crate::opt::Sgd, rank);
    let mut acc_out = Vec::new();
    let mut ralt_out = Vec::new();
    for (idx, (name, g)) in grads.iter().enumerate() {
        let mut acc = ins.matrix(&format!("acc/{name}"))?;
        if is_projectable(name) {
            let mut ralt = ins.matrix(&format!("ralt/{name}"))?;
            comp.accumulate(&mut acc, &mut ralt, g, rp::param_seed(seed, idx));
            ralt_out.push(tensor_of(ralt));
        } else {
            acc.add_scaled_inplace(g, 1.0);
        }
        acc_out.push(tensor_of(acc));
    }
    Ok((acc_out, ralt_out))
}

/// AltLoRA cycle end over a whole set: alternating-projection estimate
/// from each projectable parameter's dual sketches, naive mean elsewhere,
/// then the base-optimizer update. Returns the new opt-state tensors.
#[allow(clippy::too_many_arguments)]
fn alt_apply_set(
    opt: OptimizerKind,
    rank: usize,
    params: &mut ParamSet,
    ins: &Inputs<'_>,
    seed: u64,
    tau: f32,
    lr: f32,
    step: f32,
) -> Result<Vec<Tensor>, String> {
    let o = opt.build();
    let comp = AltLoraCompressor::new(opt.build(), rank);
    let names: Vec<String> = params.keys().cloned().collect();
    let mut out = Vec::new();
    for (idx, name) in names.iter().enumerate() {
        let w = params.get_mut(name).expect("name from keys");
        let acc = ins.matrix(&format!("acc/{name}"))?;
        let mut st: Vec<Matrix> = o
            .state_shapes(w.rows, w.cols)
            .iter()
            .map(|(slot, _)| ins.matrix(&format!("opt/{name}/{slot}")))
            .collect::<Result<_, _>>()?;
        if is_projectable(name) {
            let ralt = ins.matrix(&format!("ralt/{name}"))?;
            comp.apply_accumulated(
                w,
                &acc,
                &ralt,
                &mut st,
                rp::param_seed(seed, idx),
                tau,
                lr,
                step,
            )?;
        } else {
            let ghat = acc.scale(1.0 / tau.max(1.0));
            o.update(w, &ghat, &mut st, lr, step)?;
        }
        out.extend(st.into_iter().map(tensor_of));
    }
    Ok(out)
}

/// Read and validate the AdaRank active-rank scalars against the
/// executable's master rank.
fn active_ranks(
    ins: &Inputs<'_>,
    master: usize,
) -> Result<(usize, usize), String> {
    let rc = ins.num("rank_cur")?.round() as usize;
    let rn = ins.num("rank_next")?.round() as usize;
    if rc == 0 || rc > master || rn == 0 || rn > rc {
        return Err(format!(
            "{}: adarank ranks {rc}->{rn} invalid under master rank {master}",
            ins.ctx
        ));
    }
    Ok((rc, rn))
}

/// AdaRank ranked momentum over a whole set: projectable parameters run
/// the [`ScheduledFlora`] step at the tick's active ranks over their
/// master-shape `[n, r0]` momentum (truncate-then-transfer on shrinking
/// resamples); everything else keeps the full-space EMA. Returns
/// (opt-state, momentum) output tensors.
#[allow(clippy::too_many_arguments)]
fn adarank_step_set(
    opt: OptimizerKind,
    rank: usize,
    params: &mut ParamSet,
    grads: &ParamSet,
    ins: &Inputs<'_>,
    tick: (u64, u64, bool),
    ranks: (usize, usize),
    lr: f32,
    step: f32,
) -> Result<(Vec<Tensor>, Vec<Tensor>), String> {
    let o = opt.build();
    // the schedule itself lives in the trainer; the executor only sees
    // the already-scheduled rank_cur/rank_next scalars
    let sched = ScheduledFlora::new(
        FloraCompressor::new(opt.build(), rank),
        RankSchedule::Fixed,
    );
    let (seed_cur, seed_next, resample) = tick;
    let names: Vec<String> = params.keys().cloned().collect();
    let mut opt_out = Vec::new();
    let mut mom_out = Vec::new();
    for (idx, name) in names.iter().enumerate() {
        let w = params.get_mut(name).expect("name from keys");
        let g = grads
            .get(name)
            .ok_or_else(|| format!("missing gradient for {name}"))?;
        let mut mom = ins.matrix(&format!("mom/{name}"))?;
        let mut st: Vec<Matrix> = o
            .state_shapes(w.rows, w.cols)
            .iter()
            .map(|(slot, _)| ins.matrix(&format!("opt/{name}/{slot}")))
            .collect::<Result<_, _>>()?;
        if is_projectable(name) {
            let t = RankedTick {
                sub: SubspaceTick {
                    seed_cur: rp::param_seed(seed_cur, idx),
                    seed_next: rp::param_seed(seed_next, idx),
                    resample,
                    transfer: true,
                },
                rank_cur: ranks.0,
                rank_next: ranks.1,
            };
            sched.momentum_step(w, &mut mom, &mut st, g, t, lr, step)?;
        } else {
            let mut next = mom.scale(MOMENTUM_BETA);
            next.add_scaled_inplace(g, 1.0 - MOMENTUM_BETA);
            o.update(w, &next, &mut st, lr, step)?;
            mom = next;
        }
        opt_out.extend(st.into_iter().map(tensor_of));
        mom_out.push(tensor_of(mom));
    }
    Ok((opt_out, mom_out))
}

/// GaLore over a whole set: Adam-in-subspace with a stored projection on
/// projectable parameters (refresh regenerates it from the per-parameter
/// seed), full-space Adam on the rest. Returns the state tensors in spec
/// order (per parameter: m, [proj], v).
#[allow(clippy::too_many_arguments)]
fn galore_step_set(
    rank: usize,
    params: &mut ParamSet,
    grads: &ParamSet,
    ins: &Inputs<'_>,
    seed: u64,
    refresh: bool,
    lr: f32,
    step: f32,
) -> Result<Vec<Tensor>, String> {
    let adam = Adam::new();
    let names: Vec<String> = params.keys().cloned().collect();
    let mut out = Vec::new();
    for (idx, name) in names.iter().enumerate() {
        let w = params.get_mut(name).expect("name from keys");
        let g = grads
            .get(name)
            .ok_or_else(|| format!("missing gradient for {name}"))?;
        let mut m = ins.matrix(&format!("m/{name}"))?;
        let mut vv = ins.matrix(&format!("v/{name}"))?;
        if is_projectable(name) {
            let p = if refresh {
                rp::projection(rp::param_seed(seed, idx), rank, w.cols)
            } else {
                ins.matrix(&format!("proj/{name}"))?
            };
            let c = rp::compress(g, &p);
            let dir = adam.direction(&mut m, &mut vv, &c, step);
            let upd = rp::decompress(&dir, &p);
            w.add_scaled_inplace(&upd, -lr);
            out.push(tensor_of(m));
            out.push(tensor_of(p));
            out.push(tensor_of(vv));
        } else {
            let dir = adam.direction(&mut m, &mut vv, g, step);
            w.add_scaled_inplace(&dir, -lr);
            out.push(tensor_of(m));
            out.push(tensor_of(vv));
        }
    }
    Ok(out)
}

impl BackendExec for NativeExec {
    fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>, String> {
        let ctx = self.name.as_str();
        let ins = Inputs { specs: &self.inputs, vals: inputs, ctx };
        match self.step {
            Step::Init => {
                let vocab = self.bigram_vocab()?;
                let seed = ins.useed("seed")?;
                let mut rng = Rng::new(seed);
                let w = Matrix::gaussian(vocab, vocab, INIT_SIGMA, &mut rng);
                Ok(vec![tensor_of(w)])
            }
            Step::Eval => {
                let w = ins.matrix("params/w")?;
                let batch = ins.batch()?;
                let (loss, _) = loss_and_grad(&w, &batch, false, ctx)?;
                Ok(vec![scalar_f32(loss)])
            }
            Step::Greedy => {
                let vocab = self.bigram_vocab()?;
                let w = ins.matrix("params/w")?;
                let (rows, s, mut out, plen) = greedy_tokens(&ins, ctx)?;
                for b in 0..rows {
                    for i in plen..s {
                        let prev = out[b * s + i - 1];
                        if prev < 0 || prev as usize >= vocab {
                            return Err(format!(
                                "{ctx}: prompt token {prev} out of range"
                            ));
                        }
                        let logits = w.row(prev as usize);
                        let mut best = 0usize;
                        for (j, &x) in logits.iter().enumerate() {
                            if x > logits[best] {
                                best = j;
                            }
                        }
                        out[b * s + i] = best as i32;
                    }
                }
                Ok(vec![Tensor::I32 { shape: vec![rows, s], data: out }])
            }
            Step::Plain { opt } => {
                let mut w = ins.matrix("params/w")?;
                let mut st = ins.opt_state()?;
                let batch = ins.batch()?;
                let lr = ins.num("lr")?;
                let step = ins.num("step")?;
                let (loss, g) = loss_and_grad(&w, &batch, true, ctx)?;
                opt.build()
                    .update(&mut w, &g, &mut st, lr, step)
                    .map_err(|e| format!("{ctx}: {e}"))?;
                Ok(outputs_with_state(vec![scalar_f32(loss), tensor_of(w)], st))
            }
            Step::MicroFlora { rank } => {
                let w = ins.matrix("params/w")?;
                let mut acc = ins.matrix("acc/w")?;
                let batch = ins.batch()?;
                let seed = ins.useed("seed")?;
                let (loss, g) = loss_and_grad(&w, &batch, true, ctx)?;
                // Algorithm 1 line 9: C += G Aᵀ with the cycle's shared
                // seed (accumulation is base-optimizer-free).
                let comp = FloraCompressor::new(crate::opt::Sgd, rank);
                comp.accumulate(&mut acc, &g, seed);
                Ok(vec![scalar_f32(loss), tensor_of(acc)])
            }
            Step::MicroNaive => {
                let w = ins.matrix("params/w")?;
                let mut acc = ins.matrix("acc/w")?;
                let batch = ins.batch()?;
                let (loss, g) = loss_and_grad(&w, &batch, true, ctx)?;
                acc.add_scaled_inplace(&g, 1.0);
                Ok(vec![scalar_f32(loss), tensor_of(acc)])
            }
            Step::UpdateFlora { rank, opt } => {
                let mut w = ins.matrix("params/w")?;
                let acc = ins.matrix("acc/w")?;
                let mut st = ins.opt_state()?;
                let lr = ins.num("lr")?;
                let step = ins.num("step")?;
                let seed = ins.useed("seed")?;
                let tau = ins.num("tau")?;
                // Algorithm 1 cycle end: decompress the mean gradient with
                // the SAME seed the micros used, then base-optimizer step.
                let comp = FloraCompressor::new(opt.build(), rank);
                comp.apply_accumulated(&mut w, &acc, &mut st, seed, tau, lr, step)
                    .map_err(|e| format!("{ctx}: {e}"))?;
                Ok(outputs_with_state(vec![tensor_of(w)], st))
            }
            Step::UpdateNaive { opt } => {
                let mut w = ins.matrix("params/w")?;
                let acc = ins.matrix("acc/w")?;
                let mut st = ins.opt_state()?;
                let lr = ins.num("lr")?;
                let step = ins.num("step")?;
                let tau = ins.num("tau")?.max(1.0);
                let ghat = acc.scale(1.0 / tau);
                opt.build()
                    .update(&mut w, &ghat, &mut st, lr, step)
                    .map_err(|e| format!("{ctx}: {e}"))?;
                Ok(outputs_with_state(vec![tensor_of(w)], st))
            }
            Step::MomFlora { rank, transfer, opt } => {
                let mut w = ins.matrix("params/w")?;
                let mut mom = ins.matrix("mom/w")?;
                let mut st = ins.opt_state()?;
                let batch = ins.batch()?;
                let lr = ins.num("lr")?;
                let step = ins.num("step")?;
                let tick = SubspaceTick {
                    seed_cur: ins.useed("seed_cur")?,
                    seed_next: ins.useed("seed_next")?,
                    resample: ins.num("resample")? >= 0.5,
                    transfer,
                };
                let (loss, g) = loss_and_grad(&w, &batch, true, ctx)?;
                let comp = FloraCompressor::new(opt.build(), rank);
                comp.momentum_step(&mut w, &mut mom, &mut st, &g, tick, lr, step)
                    .map_err(|e| format!("{ctx}: {e}"))?;
                Ok(outputs_with_state(
                    vec![scalar_f32(loss), tensor_of(w), tensor_of(mom)],
                    st,
                ))
            }
            Step::MomNaive { opt } => {
                let mut w = ins.matrix("params/w")?;
                let mom = ins.matrix("mom/w")?;
                let mut st = ins.opt_state()?;
                let batch = ins.batch()?;
                let lr = ins.num("lr")?;
                let step = ins.num("step")?;
                let (loss, g) = loss_and_grad(&w, &batch, true, ctx)?;
                let mut new_mom = mom.scale(MOMENTUM_BETA);
                new_mom.add_scaled_inplace(&g, 1.0 - MOMENTUM_BETA);
                opt.build()
                    .update(&mut w, &new_mom, &mut st, lr, step)
                    .map_err(|e| format!("{ctx}: {e}"))?;
                Ok(outputs_with_state(
                    vec![scalar_f32(loss), tensor_of(w), tensor_of(new_mom)],
                    st,
                ))
            }
            Step::GaloreStep { rank } => {
                let mut w = ins.matrix("params/w")?;
                let mut m = ins.matrix("m/w")?;
                let p_in = ins.matrix("proj/w")?;
                let mut vv = ins.matrix("v/w")?;
                let batch = ins.batch()?;
                let lr = ins.num("lr")?;
                let step = ins.num("step")?;
                let seed = ins.useed("seed")?;
                let refresh = ins.num("refresh")? >= 0.5;
                // GaLore stores P (that's its memory cost); refresh swaps
                // it for a fresh seeded subspace every κ steps.
                let p = if refresh {
                    rp::projection(seed, rank, w.cols)
                } else {
                    p_in
                };
                let (loss, g) = loss_and_grad(&w, &batch, true, ctx)?;
                let c = rp::compress(&g, &p);
                // Adam-in-subspace: same moment/bias-correction rule as the
                // full Adam, applied to the compressed moments.
                let dir = Adam::new().direction(&mut m, &mut vv, &c, step);
                let upd = rp::decompress(&dir, &p);
                w.add_scaled_inplace(&upd, -lr);
                Ok(vec![
                    scalar_f32(loss),
                    tensor_of(w),
                    tensor_of(m),
                    tensor_of(p),
                    tensor_of(vv),
                ])
            }

            // ----------------------------------------------------------
            // transformer LM (lora-tiny)
            // ----------------------------------------------------------
            Step::TfInit => {
                let cfg = self.lm_cfg()?;
                Ok(set_tensors(cfg.init(ins.useed("seed")?)))
            }
            Step::TfEval => {
                let cfg = self.lm_cfg()?;
                let params = read_set(&ins, &cfg.param_shapes(), "params")?;
                let batch = ins.batch()?;
                let (loss, _) = cfg
                    .loss_and_grad(
                        &params, batch.tokens, batch.mask, batch.rows,
                        batch.seq, false,
                    )
                    .map_err(|e| format!("{ctx}: {e}"))?;
                Ok(vec![scalar_f32(loss)])
            }
            Step::TfGreedy => {
                let cfg = self.lm_cfg()?;
                let params = read_set(&ins, &cfg.param_shapes(), "params")?;
                let (rows, s, mut toks, plen) = greedy_tokens(&ins, ctx)?;
                cfg.greedy(&params, &mut toks, rows, s, plen)
                    .map_err(|e| format!("{ctx}: {e}"))?;
                Ok(vec![Tensor::I32 { shape: vec![rows, s], data: toks }])
            }
            Step::TfPlain { opt } => {
                let cfg = self.lm_cfg()?;
                let mut params = read_set(&ins, &cfg.param_shapes(), "params")?;
                let batch = ins.batch()?;
                let lr = ins.num("lr")?;
                let step = ins.num("step")?;
                let (loss, grads) = cfg
                    .loss_and_grad(
                        &params, batch.tokens, batch.mask, batch.rows,
                        batch.seq, true,
                    )
                    .map_err(|e| format!("{ctx}: {e}"))?;
                let opt_out =
                    opt_update_set(opt, &mut params, &grads, &ins, lr, step)
                        .map_err(|e| format!("{ctx}: {e}"))?;
                let mut out = vec![scalar_f32(loss)];
                out.extend(set_tensors(params));
                out.extend(opt_out);
                Ok(out)
            }
            Step::TfMicroFlora { rank } => {
                let cfg = self.lm_cfg()?;
                let params = read_set(&ins, &cfg.param_shapes(), "params")?;
                let batch = ins.batch()?;
                let seed = ins.useed("seed")?;
                let (loss, grads) = cfg
                    .loss_and_grad(
                        &params, batch.tokens, batch.mask, batch.rows,
                        batch.seq, true,
                    )
                    .map_err(|e| format!("{ctx}: {e}"))?;
                let accs = accumulate_set(Some(rank), &grads, &ins, seed)
                    .map_err(|e| format!("{ctx}: {e}"))?;
                let mut out = vec![scalar_f32(loss)];
                out.extend(accs);
                Ok(out)
            }
            Step::TfMicroNaive => {
                let cfg = self.lm_cfg()?;
                let params = read_set(&ins, &cfg.param_shapes(), "params")?;
                let batch = ins.batch()?;
                let (loss, grads) = cfg
                    .loss_and_grad(
                        &params, batch.tokens, batch.mask, batch.rows,
                        batch.seq, true,
                    )
                    .map_err(|e| format!("{ctx}: {e}"))?;
                let accs = accumulate_set(None, &grads, &ins, 0)
                    .map_err(|e| format!("{ctx}: {e}"))?;
                let mut out = vec![scalar_f32(loss)];
                out.extend(accs);
                Ok(out)
            }
            Step::TfUpdateFlora { rank, opt } => {
                let cfg = self.lm_cfg()?;
                let mut params = read_set(&ins, &cfg.param_shapes(), "params")?;
                let lr = ins.num("lr")?;
                let step = ins.num("step")?;
                let seed = ins.useed("seed")?;
                let tau = ins.num("tau")?;
                let opt_out = apply_accumulated_set(
                    opt, Some(rank), &mut params, &ins, seed, tau, lr, step,
                )
                .map_err(|e| format!("{ctx}: {e}"))?;
                let mut out = set_tensors(params);
                out.extend(opt_out);
                Ok(out)
            }
            Step::TfUpdateNaive { opt } => {
                let cfg = self.lm_cfg()?;
                let mut params = read_set(&ins, &cfg.param_shapes(), "params")?;
                let lr = ins.num("lr")?;
                let step = ins.num("step")?;
                let tau = ins.num("tau")?;
                let opt_out = apply_accumulated_set(
                    opt, None, &mut params, &ins, 0, tau, lr, step,
                )
                .map_err(|e| format!("{ctx}: {e}"))?;
                let mut out = set_tensors(params);
                out.extend(opt_out);
                Ok(out)
            }
            Step::TfMomFlora { rank, transfer, opt } => {
                let cfg = self.lm_cfg()?;
                let mut params = read_set(&ins, &cfg.param_shapes(), "params")?;
                let batch = ins.batch()?;
                let lr = ins.num("lr")?;
                let step = ins.num("step")?;
                let tick = (
                    ins.useed("seed_cur")?,
                    ins.useed("seed_next")?,
                    ins.num("resample")? >= 0.5,
                );
                let (loss, grads) = cfg
                    .loss_and_grad(
                        &params, batch.tokens, batch.mask, batch.rows,
                        batch.seq, true,
                    )
                    .map_err(|e| format!("{ctx}: {e}"))?;
                let (opt_out, mom_out) = momentum_step_set(
                    opt, Some(rank), transfer, &mut params, &grads, &ins,
                    Some(tick), lr, step,
                )
                .map_err(|e| format!("{ctx}: {e}"))?;
                let mut out = vec![scalar_f32(loss)];
                out.extend(set_tensors(params));
                out.extend(opt_out);
                out.extend(mom_out);
                Ok(out)
            }
            Step::TfMomNaive { opt } => {
                let cfg = self.lm_cfg()?;
                let mut params = read_set(&ins, &cfg.param_shapes(), "params")?;
                let batch = ins.batch()?;
                let lr = ins.num("lr")?;
                let step = ins.num("step")?;
                let (loss, grads) = cfg
                    .loss_and_grad(
                        &params, batch.tokens, batch.mask, batch.rows,
                        batch.seq, true,
                    )
                    .map_err(|e| format!("{ctx}: {e}"))?;
                let (opt_out, mom_out) = momentum_step_set(
                    opt, None, false, &mut params, &grads, &ins, None, lr, step,
                )
                .map_err(|e| format!("{ctx}: {e}"))?;
                let mut out = vec![scalar_f32(loss)];
                out.extend(set_tensors(params));
                out.extend(opt_out);
                out.extend(mom_out);
                Ok(out)
            }
            Step::TfGalore { rank } => {
                let cfg = self.lm_cfg()?;
                let mut params = read_set(&ins, &cfg.param_shapes(), "params")?;
                let batch = ins.batch()?;
                let lr = ins.num("lr")?;
                let step = ins.num("step")?;
                let seed = ins.useed("seed")?;
                let refresh = ins.num("refresh")? >= 0.5;
                let (loss, grads) = cfg
                    .loss_and_grad(
                        &params, batch.tokens, batch.mask, batch.rows,
                        batch.seq, true,
                    )
                    .map_err(|e| format!("{ctx}: {e}"))?;
                let state = galore_step_set(
                    rank, &mut params, &grads, &ins, seed, refresh, lr, step,
                )
                .map_err(|e| format!("{ctx}: {e}"))?;
                let mut out = vec![scalar_f32(loss)];
                out.extend(set_tensors(params));
                out.extend(state);
                Ok(out)
            }

            // ----------------------------------------------------------
            // adaptive-rank compressor grid (AltLoRA + AdaRank)
            // ----------------------------------------------------------
            Step::TfMicroAlt { rank } => {
                let cfg = self.lm_cfg()?;
                let params = read_set(&ins, &cfg.param_shapes(), "params")?;
                let batch = ins.batch()?;
                let seed = ins.useed("seed")?;
                let (loss, grads) = cfg
                    .loss_and_grad(
                        &params, batch.tokens, batch.mask, batch.rows,
                        batch.seq, true,
                    )
                    .map_err(|e| format!("{ctx}: {e}"))?;
                let (accs, ralts) = alt_accumulate_set(rank, &grads, &ins, seed)
                    .map_err(|e| format!("{ctx}: {e}"))?;
                let mut out = vec![scalar_f32(loss)];
                out.extend(accs);
                out.extend(ralts);
                Ok(out)
            }
            Step::TfUpdateAlt { rank, opt } => {
                let cfg = self.lm_cfg()?;
                let mut params = read_set(&ins, &cfg.param_shapes(), "params")?;
                let lr = ins.num("lr")?;
                let step = ins.num("step")?;
                let seed = ins.useed("seed")?;
                let tau = ins.num("tau")?;
                let opt_out = alt_apply_set(
                    opt, rank, &mut params, &ins, seed, tau, lr, step,
                )
                .map_err(|e| format!("{ctx}: {e}"))?;
                let mut out = set_tensors(params);
                out.extend(opt_out);
                Ok(out)
            }
            Step::TfMomAdaRank { rank, opt } => {
                let cfg = self.lm_cfg()?;
                let mut params = read_set(&ins, &cfg.param_shapes(), "params")?;
                let batch = ins.batch()?;
                let lr = ins.num("lr")?;
                let step = ins.num("step")?;
                let tick = (
                    ins.useed("seed_cur")?,
                    ins.useed("seed_next")?,
                    ins.num("resample")? >= 0.5,
                );
                let ranks = active_ranks(&ins, rank)?;
                let (loss, grads) = cfg
                    .loss_and_grad(
                        &params, batch.tokens, batch.mask, batch.rows,
                        batch.seq, true,
                    )
                    .map_err(|e| format!("{ctx}: {e}"))?;
                let (opt_out, mom_out) = adarank_step_set(
                    opt, rank, &mut params, &grads, &ins, tick, ranks, lr,
                    step,
                )
                .map_err(|e| format!("{ctx}: {e}"))?;
                let mut out = vec![scalar_f32(loss)];
                out.extend(set_tensors(params));
                out.extend(opt_out);
                out.extend(mom_out);
                Ok(out)
            }

            // ----------------------------------------------------------
            // LoRA adapter baseline (frozen base + trainable patches)
            // ----------------------------------------------------------
            Step::LoraInit { rank } => {
                let cfg = self.lm_cfg()?;
                let base = read_set(&ins, &cfg.param_shapes(), "params")?;
                let adapter = LoraAdapter::new(cfg.param_shapes(), rank);
                Ok(set_tensors(
                    adapter.init_trainable(&base, ins.useed("seed")?),
                ))
            }
            Step::LoraEval { rank } => {
                let cfg = self.lm_cfg()?;
                let adapter = LoraAdapter::new(cfg.param_shapes(), rank);
                let base = read_set(&ins, &cfg.param_shapes(), "params")?;
                let train = read_set(&ins, &adapter.trainable_shapes(), "train")?;
                let merged = adapter.merge(&base, &train);
                let batch = ins.batch()?;
                let (loss, _) = cfg
                    .loss_and_grad(
                        &merged, batch.tokens, batch.mask, batch.rows,
                        batch.seq, false,
                    )
                    .map_err(|e| format!("{ctx}: {e}"))?;
                Ok(vec![scalar_f32(loss)])
            }
            Step::LoraGreedy { rank } => {
                let cfg = self.lm_cfg()?;
                let adapter = LoraAdapter::new(cfg.param_shapes(), rank);
                let base = read_set(&ins, &cfg.param_shapes(), "params")?;
                let train = read_set(&ins, &adapter.trainable_shapes(), "train")?;
                let merged = adapter.merge(&base, &train);
                let (rows, s, mut toks, plen) = greedy_tokens(&ins, ctx)?;
                cfg.greedy(&merged, &mut toks, rows, s, plen)
                    .map_err(|e| format!("{ctx}: {e}"))?;
                Ok(vec![Tensor::I32 { shape: vec![rows, s], data: toks }])
            }
            Step::LoraMicro { rank } => {
                let cfg = self.lm_cfg()?;
                let adapter = LoraAdapter::new(cfg.param_shapes(), rank);
                let base = read_set(&ins, &cfg.param_shapes(), "params")?;
                let train = read_set(&ins, &adapter.trainable_shapes(), "train")?;
                let merged = adapter.merge(&base, &train);
                let batch = ins.batch()?;
                let (loss, grads) = cfg
                    .loss_and_grad(
                        &merged, batch.tokens, batch.mask, batch.rows,
                        batch.seq, true,
                    )
                    .map_err(|e| format!("{ctx}: {e}"))?;
                let tgrads = adapter.train_grads(&train, &grads);
                let accs = accumulate_set(None, &tgrads, &ins, 0)
                    .map_err(|e| format!("{ctx}: {e}"))?;
                let mut out = vec![scalar_f32(loss)];
                out.extend(accs);
                Ok(out)
            }
            Step::LoraUpdate { rank, opt } => {
                let cfg = self.lm_cfg()?;
                let adapter = LoraAdapter::new(cfg.param_shapes(), rank);
                let mut train =
                    read_set(&ins, &adapter.trainable_shapes(), "train")?;
                let lr = ins.num("lr")?;
                let step = ins.num("step")?;
                let tau = ins.num("tau")?;
                let opt_out = apply_accumulated_set(
                    opt, None, &mut train, &ins, 0, tau, lr, step,
                )
                .map_err(|e| format!("{ctx}: {e}"))?;
                let mut out = set_tensors(train);
                out.extend(opt_out);
                Ok(out)
            }
            Step::LoraMom { rank, opt } => {
                let cfg = self.lm_cfg()?;
                let adapter = LoraAdapter::new(cfg.param_shapes(), rank);
                let base = read_set(&ins, &cfg.param_shapes(), "params")?;
                let mut train =
                    read_set(&ins, &adapter.trainable_shapes(), "train")?;
                let merged = adapter.merge(&base, &train);
                let batch = ins.batch()?;
                let lr = ins.num("lr")?;
                let step = ins.num("step")?;
                let (loss, grads) = cfg
                    .loss_and_grad(
                        &merged, batch.tokens, batch.mask, batch.rows,
                        batch.seq, true,
                    )
                    .map_err(|e| format!("{ctx}: {e}"))?;
                let tgrads = adapter.train_grads(&train, &grads);
                let (opt_out, mom_out) = momentum_step_set(
                    opt, None, false, &mut train, &tgrads, &ins, None, lr, step,
                )
                .map_err(|e| format!("{ctx}: {e}"))?;
                let mut out = vec![scalar_f32(loss)];
                out.extend(set_tensors(train));
                out.extend(opt_out);
                out.extend(mom_out);
                Ok(out)
            }

            // ----------------------------------------------------------
            // ViT (vit-tiny)
            // ----------------------------------------------------------
            Step::VitInit => {
                let cfg = self.vit_cfg()?;
                Ok(set_tensors(cfg.init(ins.useed("seed")?)))
            }
            Step::VitEval => {
                let cfg = self.vit_cfg()?;
                let params = read_set(&ins, &cfg.param_shapes(), "params")?;
                let (images, labels) = vit_batch(&ins, ctx)?;
                let (loss, preds, _) = cfg
                    .loss_preds_grad(&params, images, labels, false)
                    .map_err(|e| format!("{ctx}: {e}"))?;
                Ok(vec![
                    scalar_f32(loss),
                    Tensor::I32 { shape: vec![labels.len()], data: preds },
                ])
            }
            Step::VitPlain { opt } => {
                let cfg = self.vit_cfg()?;
                let mut params = read_set(&ins, &cfg.param_shapes(), "params")?;
                let (images, labels) = vit_batch(&ins, ctx)?;
                let lr = ins.num("lr")?;
                let step = ins.num("step")?;
                let (loss, _, grads) = cfg
                    .loss_preds_grad(&params, images, labels, true)
                    .map_err(|e| format!("{ctx}: {e}"))?;
                let opt_out =
                    opt_update_set(opt, &mut params, &grads, &ins, lr, step)
                        .map_err(|e| format!("{ctx}: {e}"))?;
                let mut out = vec![scalar_f32(loss)];
                out.extend(set_tensors(params));
                out.extend(opt_out);
                Ok(out)
            }
            Step::VitMomFlora { rank, opt } => {
                let cfg = self.vit_cfg()?;
                let mut params = read_set(&ins, &cfg.param_shapes(), "params")?;
                let (images, labels) = vit_batch(&ins, ctx)?;
                let lr = ins.num("lr")?;
                let step = ins.num("step")?;
                let tick = (
                    ins.useed("seed_cur")?,
                    ins.useed("seed_next")?,
                    ins.num("resample")? >= 0.5,
                );
                let (loss, _, grads) = cfg
                    .loss_preds_grad(&params, images, labels, true)
                    .map_err(|e| format!("{ctx}: {e}"))?;
                let (opt_out, mom_out) = momentum_step_set(
                    opt, Some(rank), true, &mut params, &grads, &ins,
                    Some(tick), lr, step,
                )
                .map_err(|e| format!("{ctx}: {e}"))?;
                let mut out = vec![scalar_f32(loss)];
                out.extend(set_tensors(params));
                out.extend(opt_out);
                out.extend(mom_out);
                Ok(out)
            }
            Step::VitAltStep { rank, opt } => {
                let cfg = self.vit_cfg()?;
                let mut params = read_set(&ins, &cfg.param_shapes(), "params")?;
                let (images, labels) = vit_batch(&ins, ctx)?;
                let lr = ins.num("lr")?;
                let step = ins.num("step")?;
                let seed_cur = ins.useed("seed_cur")?;
                let (loss, _, grads) = cfg
                    .loss_preds_grad(&params, images, labels, true)
                    .map_err(|e| format!("{ctx}: {e}"))?;
                // fused τ=1 AltLoRA: sketch and reconstruct each
                // projectable gradient with a per-step seed derived from
                // the cycle seed — no persistent method state
                let comp = AltLoraCompressor::new(crate::opt::Sgd, rank);
                let step_seed = derive_seed(seed_cur, step as u64);
                let mut eff = ParamSet::new();
                for (idx, (name, g)) in grads.iter().enumerate() {
                    let ghat = if is_projectable(name) {
                        comp.estimate_from_grad(g, rp::param_seed(step_seed, idx))
                            .map_err(|e| format!("{ctx}: {name}: {e}"))?
                    } else {
                        g.clone()
                    };
                    eff.insert(name.clone(), ghat);
                }
                let opt_out =
                    opt_update_set(opt, &mut params, &eff, &ins, lr, step)
                        .map_err(|e| format!("{ctx}: {e}"))?;
                let mut out = vec![scalar_f32(loss)];
                out.extend(set_tensors(params));
                out.extend(opt_out);
                Ok(out)
            }
            Step::VitAdaRank { rank, opt } => {
                let cfg = self.vit_cfg()?;
                let mut params = read_set(&ins, &cfg.param_shapes(), "params")?;
                let (images, labels) = vit_batch(&ins, ctx)?;
                let lr = ins.num("lr")?;
                let step = ins.num("step")?;
                let tick = (
                    ins.useed("seed_cur")?,
                    ins.useed("seed_next")?,
                    ins.num("resample")? >= 0.5,
                );
                let ranks = active_ranks(&ins, rank)?;
                let (loss, _, grads) = cfg
                    .loss_preds_grad(&params, images, labels, true)
                    .map_err(|e| format!("{ctx}: {e}"))?;
                let (opt_out, mom_out) = adarank_step_set(
                    opt, rank, &mut params, &grads, &ins, tick, ranks, lr,
                    step,
                )
                .map_err(|e| format!("{ctx}: {e}"))?;
                let mut out = vec![scalar_f32(loss)];
                out.extend(set_tensors(params));
                out.extend(opt_out);
                out.extend(mom_out);
                Ok(out)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::values::{
        scalar_f32, scalar_u32, tensor_f32, tensor_i32, zeros_for,
    };

    fn exec<'a>(backend: &'a NativeBackend, name: &str) -> &'a Rc<NativeExec> {
        backend.execs.get(name).unwrap()
    }

    /// Mini-harness for multi-tensor executables: inputs are pulled from a
    /// name→tensor map in manifest order, outputs are routed back into it
    /// by name. Returns the loss when the step produces one.
    fn run_named(
        manifest: &Manifest,
        backend: &NativeBackend,
        name: &str,
        vals: &mut BTreeMap<String, Tensor>,
    ) -> Option<f32> {
        let info = manifest.executable(name).unwrap();
        let e = exec(backend, name);
        let inputs: Vec<Tensor> = info
            .inputs
            .iter()
            .map(|t| {
                vals.get(&t.name)
                    .unwrap_or_else(|| panic!("{name}: missing {}", t.name))
                    .clone()
            })
            .collect();
        let outs = e.run(&inputs).unwrap();
        assert_eq!(outs.len(), info.outputs.len(), "{name}: arity");
        let mut loss = None;
        for (spec, val) in info.outputs.iter().zip(outs) {
            if spec.name == "loss" {
                loss = val.first_f32().ok();
            }
            vals.insert(spec.name.clone(), val);
        }
        loss
    }

    fn toy_batch(v: usize, s: usize) -> (Tensor, Tensor) {
        // two rows: a repeating 5,6,7,... ramp with the tail masked in
        let rows = 2usize;
        let mut toks = vec![0i32; rows * s];
        let mut mask = vec![0.0f32; rows * s];
        for b in 0..rows {
            for i in 0..s {
                toks[b * s + i] = (5 + (b + i) % (v - 5)) as i32;
                if i >= s / 2 {
                    mask[b * s + i] = 1.0;
                }
            }
        }
        (
            Tensor::I32 { shape: vec![rows, s], data: toks },
            tensor_f32(&[rows, s], &mask).unwrap(),
        )
    }

    #[test]
    fn catalog_and_manifest_agree() {
        let (manifest, backend) = catalog();
        assert_eq!(manifest.executables.len(), backend.execs.len());
        for name in manifest.executables.keys() {
            assert!(backend.execs.contains_key(name), "missing exec {name}");
        }
        // ABI arity spot checks: the sgd names keep their PR-1 shape...
        let e = manifest.executable("lm-tiny/plain_step_sgd").unwrap();
        assert_eq!(e.inputs.len(), 5);
        assert_eq!(e.outputs.len(), 2);
        let e = manifest.executable("lm-tiny/galore_step_r8").unwrap();
        assert_eq!(e.inputs.len(), 10);
        assert_eq!(e.outputs.len(), 5);
        // ...and the adam/adafactor variants splice their opt state in.
        let e = manifest.executable("lm-tiny/plain_step_adam").unwrap();
        assert_eq!(e.inputs.len(), 7);
        assert_eq!(e.outputs.len(), 4);
        assert_eq!(e.inputs[1].name, "opt/m/w");
        assert_eq!(e.inputs[2].name, "opt/v/w");
        let e = manifest
            .executable("lm-tiny/update_flora_r8_adafactor")
            .unwrap();
        assert_eq!(e.inputs.len(), 8);
        assert_eq!(e.outputs.len(), 3);
        assert_eq!(e.inputs[2].name, "opt/vr/w");
        assert_eq!(e.inputs[2].shape, vec![64, 1]);
        assert_eq!(e.inputs[3].name, "opt/vc/w");
        assert_eq!(e.inputs[3].shape, vec![1, 64]);
        let e = manifest
            .executable("lm-tiny/mom_step_flora_r8_adam")
            .unwrap();
        assert_eq!(e.inputs.len(), 11);
        assert_eq!(e.outputs.len(), 5);
    }

    #[test]
    fn catalog_covers_every_optimizer() {
        let (manifest, _) = catalog();
        for opt in OptimizerKind::ALL {
            let o = opt.name();
            for exe in [
                format!("lm-tiny/plain_step_{o}"),
                format!("lm-tiny/update_naive_{o}"),
                format!("lm-tiny/update_flora_r8_{o}"),
                format!("lm-tiny/mom_step_naive_{o}"),
                format!("lm-tiny/mom_step_flora_r8_{o}"),
                format!("lm-tiny/mom_step_flora_notransfer_r8_{o}"),
            ] {
                assert!(
                    manifest.executables.contains_key(&exe),
                    "missing {exe}"
                );
            }
        }
    }

    #[test]
    fn init_is_deterministic_per_seed() {
        let (_, backend) = catalog();
        let init = exec(&backend, "lm-tiny/init");
        let a = init.run(&[scalar_u32(7)]).unwrap();
        let b = init.run(&[scalar_u32(7)]).unwrap();
        let c = init.run(&[scalar_u32(8)]).unwrap();
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert_eq!(a[0].element_count(), 64 * 64);
    }

    #[test]
    fn plain_step_descends_on_repeated_batch() {
        let (_, backend) = catalog();
        let init = exec(&backend, "lm-tiny/init");
        let step = exec(&backend, "lm-tiny/plain_step_sgd");
        let (toks, mask) = toy_batch(64, 32);
        let mut params = init.run(&[scalar_u32(0)]).unwrap().remove(0);
        let mut losses = Vec::new();
        for s in 0..30 {
            let outs = step
                .run(&[
                    params.clone(),
                    toks.clone(),
                    mask.clone(),
                    scalar_f32(0.5),
                    scalar_f32(s as f32),
                ])
                .unwrap();
            losses.push(outs[0].first_f32().unwrap());
            params = outs.into_iter().nth(1).unwrap();
        }
        let first = losses[0];
        let last = *losses.last().unwrap();
        assert!(first.is_finite() && last.is_finite());
        // init is near-uniform: loss ≈ ln 64; a fixed batch must overfit
        assert!((first - (64f32).ln()).abs() < 0.5, "first={first}");
        assert!(last < first - 0.5, "no descent: {first} -> {last}");
    }

    #[test]
    fn plain_step_adam_descends_and_threads_opt_state() {
        let (_, backend) = catalog();
        let init = exec(&backend, "lm-tiny/init");
        let step = exec(&backend, "lm-tiny/plain_step_adam");
        let (toks, mask) = toy_batch(64, 32);
        let mut params = init.run(&[scalar_u32(0)]).unwrap().remove(0);
        let zeros = tensor_f32(&[64, 64], &[0.0; 64 * 64]).unwrap();
        let (mut m, mut v) = (zeros.clone(), zeros);
        let mut losses = Vec::new();
        for s in 0..30 {
            let outs = step
                .run(&[
                    params.clone(),
                    m.clone(),
                    v.clone(),
                    toks.clone(),
                    mask.clone(),
                    scalar_f32(0.05),
                    scalar_f32(s as f32),
                ])
                .unwrap();
            losses.push(outs[0].first_f32().unwrap());
            let mut it = outs.into_iter();
            it.next(); // loss
            params = it.next().unwrap();
            m = it.next().unwrap();
            v = it.next().unwrap();
        }
        // the second moment must be strictly positive after 30 steps
        assert!(v.to_f32_vec().unwrap().iter().any(|&x| x > 0.0));
        let first = losses[0];
        let last = *losses.last().unwrap();
        assert!((first - (64f32).ln()).abs() < 0.5, "first={first}");
        assert!(last < first - 0.5, "no adam descent: {first} -> {last}");
    }

    #[test]
    fn plain_gradient_matches_finite_differences() {
        let (toks, mask) = toy_batch(64, 32);
        let batch = batch_of(&toks, &mask, "t").unwrap();
        let mut rng = Rng::new(3);
        let w = Matrix::gaussian(64, 64, 0.3, &mut rng);
        let (_, g) = loss_and_grad(&w, &batch, true, "t").unwrap();
        let eps = 1e-3f32;
        for &(i, j) in &[(5usize, 6usize), (6, 7), (9, 10)] {
            let mut wp = w.clone();
            *wp.at_mut(i, j) += eps;
            let mut wm = w.clone();
            *wm.at_mut(i, j) -= eps;
            let (lp, _) = loss_and_grad(&wp, &batch, false, "t").unwrap();
            let (lm, _) = loss_and_grad(&wm, &batch, false, "t").unwrap();
            let fd = (lp - lm) / (2.0 * eps);
            let an = g.at(i, j);
            assert!(
                (fd - an).abs() < 1e-2 * (1.0 + fd.abs().max(an.abs())),
                "({i},{j}): fd={fd} analytic={an}"
            );
        }
    }

    #[test]
    fn flora_micro_accumulates_compressed_gradient() {
        let (_, backend) = catalog();
        let init = exec(&backend, "lm-tiny/init");
        let micro = exec(&backend, "lm-tiny/micro_flora_r4");
        let (toks, mask) = toy_batch(64, 32);
        let params = init.run(&[scalar_u32(1)]).unwrap().remove(0);
        let zero_acc = tensor_f32(&[64, 4], &[0.0; 64 * 4]).unwrap();
        let outs = micro
            .run(&[
                params.clone(),
                zero_acc.clone(),
                toks.clone(),
                mask.clone(),
                scalar_u32(99),
            ])
            .unwrap();
        let acc1 = outs[1].to_f32_vec().unwrap();
        assert_eq!(acc1.len(), 64 * 4);
        assert!(acc1.iter().any(|&x| x != 0.0));
        // two identical micros accumulate to exactly twice one micro
        let outs2 = micro
            .run(&[params, outs[1].clone(), toks, mask, scalar_u32(99)])
            .unwrap();
        let acc2 = outs2[1].to_f32_vec().unwrap();
        for (a2, a1) in acc2.iter().zip(acc1.iter()) {
            assert!((a2 - 2.0 * a1).abs() < 1e-4, "{a2} vs 2*{a1}");
        }
    }

    #[test]
    fn momentum_transfer_fires_only_on_resample() {
        let (_, backend) = catalog();
        let init = exec(&backend, "lm-tiny/init");
        let step = exec(&backend, "lm-tiny/mom_step_flora_r4_sgd");
        let (toks, mask) = toy_batch(64, 32);
        let params = init.run(&[scalar_u32(2)]).unwrap().remove(0);
        let mom = tensor_f32(&[64, 4], &[0.1; 64 * 4]).unwrap();
        let base = vec![
            params,
            mom,
            toks,
            mask,
            scalar_f32(0.1),
            scalar_f32(0.0),
            scalar_u32(11),
            scalar_u32(12),
            scalar_f32(0.0),
        ];
        let quiet = step.run(&base).unwrap();
        let mut resampled_in = base.clone();
        resampled_in[8] = scalar_f32(1.0);
        let resampled = step.run(&resampled_in).unwrap();
        // the transfer rotates the momentum into a new subspace, so the
        // resulting EMA state must differ from the quiet step's
        assert_ne!(quiet[2], resampled[2]);
    }

    #[test]
    fn transformer_and_vit_catalogs_cover_every_optimizer() {
        let (manifest, _) = catalog();
        for opt in OptimizerKind::ALL {
            let o = opt.name();
            for exe in [
                format!("lora-tiny/plain_step_{o}"),
                format!("lora-tiny/update_flora_r8_{o}"),
                format!("lora-tiny/update_naive_{o}"),
                format!("lora-tiny/mom_step_flora_r8_{o}"),
                format!("lora-tiny/mom_step_flora_notransfer_r8_{o}"),
                format!("lora-tiny/mom_step_naive_{o}"),
                format!("lora-tiny/lora_r8_update_{o}"),
                format!("lora-tiny/lora_r8_mom_step_{o}"),
                format!("lora-tiny/update_r8_{o}_altlora"),
                format!("lora-tiny/mom_step_r8_{o}_adarank"),
                format!("vit-tiny/step_{o}"),
                format!("vit-tiny/step_flora_r8_{o}"),
                format!("vit-tiny/step_r8_{o}_altlora"),
                format!("vit-tiny/step_r8_{o}_adarank"),
            ] {
                assert!(
                    manifest.executables.contains_key(&exe),
                    "missing {exe}"
                );
            }
        }
        for exe in [
            "lora-tiny/init",
            "lora-tiny/eval",
            "lora-tiny/greedy",
            "lora-tiny/micro_naive",
            "lora-tiny/micro_flora_r8",
            "lora-tiny/micro_r8_altlora",
            "lora-tiny/lora_r8_init",
            "lora-tiny/lora_r8_micro",
            "lora-tiny/lora_r8_eval",
            "lora-tiny/lora_r8_greedy",
            "lora-tiny/galore_step_r8",
            "vit-tiny/init",
            "vit-tiny/eval",
        ] {
            assert!(manifest.executables.contains_key(exe), "missing {exe}");
        }
        assert_eq!(manifest.models["lora-tiny"].kind, "lm");
        assert_eq!(manifest.models["vit-tiny"].kind, "vit");
        assert_eq!(manifest.models["vit-tiny"].get("image_size"), Some(8));
        assert_eq!(manifest.models["vit-tiny"].get("n_classes"), Some(10));
    }

    #[test]
    fn size_grid_registers_every_family_size() {
        let (manifest, _) = catalog();
        for model in ["lora-tiny", "lora-small", "lora-base"] {
            for entry in [
                "init",
                "eval",
                "greedy",
                "plain_step_sgd",
                "micro_flora_r8",
                "update_flora_r8_adafactor",
                "mom_step_flora_r8_adam",
                "mom_step_flora_notransfer_r8_sgd",
                "micro_r8_altlora",
                "update_r8_adafactor_altlora",
                "mom_step_r8_adam_adarank",
                "lora_r8_init",
                "lora_r8_update_adam",
                "galore_step_r8",
            ] {
                let exe = format!("{model}/{entry}");
                assert!(manifest.executables.contains_key(&exe), "missing {exe}");
            }
        }
        for model in ["vit-tiny", "vit-small"] {
            for entry in [
                "init",
                "eval",
                "step_adam",
                "step_flora_r8_adafactor",
                "step_r8_adam_altlora",
                "step_r8_sgd_adarank",
            ] {
                let exe = format!("{model}/{entry}");
                assert!(manifest.executables.contains_key(&exe), "missing {exe}");
            }
        }
        // the grid really is a size grid: d_model strictly grows
        let d = |m: &str| manifest.models[m].get("d_model").unwrap();
        assert!(d("lora-tiny") < d("lora-small") && d("lora-small") < d("lora-base"));
        assert!(d("vit-tiny") < d("vit-small"));
        assert_eq!(manifest.models["lora-small"].get("n_layers"), Some(2));
        assert_eq!(manifest.models["vit-small"].get("image_size"), Some(16));
    }

    #[test]
    fn catalog_summary_groups_by_family_and_collapses_variants() {
        let (manifest, _) = catalog();
        let s = catalog_summary(&manifest);
        for header in [
            "lm family (sizes: lm-tiny < lm-small < lm-base):",
            "lora family (sizes: lora-tiny < lora-small < lora-base):",
            "vit family (sizes: vit-tiny < vit-small):",
        ] {
            assert!(s.contains(header), "missing {header:?} in:\n{s}");
        }
        // rank/optimizer variants are collapsed with their counts...
        assert!(s.contains("plain_step_{opt}  x4"), "{s}");
        assert!(s.contains("mom_step_flora_r{N}_{opt}  x16"), "{s}");
        assert!(s.contains("lora_r{N}_update_{opt}  x16"), "{s}");
        assert!(s.contains("galore_step_r{N}  x4"), "{s}");
        // ...including the compressor-tagged grid entries...
        assert!(s.contains("micro_r{N}_altlora  x4"), "{s}");
        assert!(s.contains("update_r{N}_{opt}_altlora  x16"), "{s}");
        assert!(s.contains("mom_step_r{N}_{opt}_adarank  x16"), "{s}");
        assert!(s.contains("step_r{N}_{opt}_altlora  x16"), "{s}");
        assert!(s.contains("step_r{N}_{opt}_adarank  x16"), "{s}");
        // ...so no raw variant names leak through
        assert!(!s.contains("plain_step_adam"), "{s}");
        assert!(!s.contains("_r8"), "{s}");
        assert_eq!(collapse_entry("mom_step_flora_notransfer_r16_adafactor_nofactor"),
            "mom_step_flora_notransfer_r{N}_{opt}");
        assert_eq!(collapse_entry("micro_naive"), "micro_naive");
        // compressor tags survive the collapse without exploding it
        assert_eq!(
            collapse_entry("update_r8_adafactor_nofactor_altlora"),
            "update_r{N}_{opt}_altlora"
        );
        assert_eq!(
            collapse_entry("mom_step_r16_adam_adarank"),
            "mom_step_r{N}_{opt}_adarank"
        );
        assert_eq!(
            collapse_entry("step_r4_sgd_adarank"),
            "step_r{N}_{opt}_adarank"
        );
    }

    #[test]
    fn catalog_summary_marks_dp_capable_models() {
        let (manifest, _) = catalog();
        let s = catalog_summary(&manifest);
        // every train-dp-capable model (the native transformer LM grid)
        // carries the [dp] tag; bigram LMs and ViTs do not
        for name in ["lora-tiny", "lora-small", "lora-base"] {
            let line = s
                .lines()
                .find(|l| l.trim_start().starts_with(&format!("{name} (")))
                .unwrap_or_else(|| panic!("no summary line for {name}:\n{s}"));
            assert!(line.contains("[dp]"), "{line}");
        }
        for name in ["lm-small", "vit-tiny"] {
            let line = s
                .lines()
                .find(|l| l.trim_start().starts_with(&format!("{name} (")))
                .unwrap_or_else(|| panic!("no summary line for {name}:\n{s}"));
            assert!(!line.contains("[dp]"), "{line}");
        }
        assert!(s.contains("train-dp"), "legend missing:\n{s}");
    }

    #[test]
    fn compile_error_names_the_model_families() {
        let (_, mut backend) = catalog();
        let info = ExecutableInfo {
            name: "nope/step".into(),
            file: PathBuf::from("native"),
            model: "nope".into(),
            inputs: vec![],
            outputs: vec![],
        };
        let err = backend.compile(&info).err().expect("unknown exe accepted");
        for m in ["lm-tiny", "lm-small", "lm-base", "lora-tiny", "vit-tiny"] {
            assert!(err.contains(m), "error does not name {m}: {err}");
        }
    }

    #[test]
    fn transformer_plain_step_descends_on_repeated_batch() {
        let (manifest, backend) = catalog();
        let mut vals = BTreeMap::new();
        vals.insert("seed".to_string(), scalar_u32(0));
        run_named(&manifest, &backend, "lora-tiny/init", &mut vals);
        let (toks, mask) = toy_batch(64, 16);
        vals.insert("batch/tokens".to_string(), toks);
        vals.insert("batch/mask".to_string(), mask);
        vals.insert("lr".to_string(), scalar_f32(0.5));
        let mut losses = Vec::new();
        for s in 0..30 {
            vals.insert("step".to_string(), scalar_f32(s as f32));
            let loss = run_named(
                &manifest,
                &backend,
                "lora-tiny/plain_step_sgd",
                &mut vals,
            )
            .unwrap();
            losses.push(loss);
        }
        let first = losses[0];
        let last = *losses.last().unwrap();
        assert!(losses.iter().all(|l| l.is_finite()), "{losses:?}");
        assert!((first - (64f32).ln()).abs() < 0.5, "first={first}");
        assert!(last < first - 0.3, "no descent: {first} -> {last}");
    }

    #[test]
    fn lora_init_and_micro_follow_the_chain_rule() {
        let (manifest, backend) = catalog();
        let mut vals = BTreeMap::new();
        vals.insert("seed".to_string(), scalar_u32(2));
        run_named(&manifest, &backend, "lora-tiny/init", &mut vals);
        run_named(&manifest, &backend, "lora-tiny/lora_r4_init", &mut vals);
        // B halves start at zero, A halves are Gaussian
        let b = vals.get("train/lora_B/layer0/attn/wq").unwrap();
        assert!(b.to_f32_vec().unwrap().iter().all(|&x| x == 0.0));
        let a = vals.get("train/lora_A/layer0/attn/wq").unwrap();
        assert!(a.to_f32_vec().unwrap().iter().any(|&x| x != 0.0));
        let (toks, mask) = toy_batch(64, 16);
        vals.insert("batch/tokens".to_string(), toks);
        vals.insert("batch/mask".to_string(), mask);
        let info = manifest.executable("lora-tiny/lora_r4_micro").unwrap();
        for t in &info.inputs {
            if t.name.starts_with("acc/") {
                vals.insert(t.name.clone(), zeros_for(t).unwrap());
            }
        }
        let loss =
            run_named(&manifest, &backend, "lora-tiny/lora_r4_micro", &mut vals)
                .unwrap();
        assert!(loss.is_finite());
        // dB = dW·Aᵀ is nonzero; dA = Bᵀ·dW is exactly zero while B = 0
        let accb = vals
            .get("acc/lora_B/layer0/attn/wq")
            .unwrap()
            .to_f32_vec()
            .unwrap();
        assert!(accb.iter().any(|&x| x != 0.0));
        let acca = vals
            .get("acc/lora_A/layer0/attn/wq")
            .unwrap()
            .to_f32_vec()
            .unwrap();
        assert!(acca.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn vit_step_adam_descends_and_eval_reports_preds() {
        let (manifest, backend) = catalog();
        let mut vals = BTreeMap::new();
        vals.insert("seed".to_string(), scalar_u32(1));
        run_named(&manifest, &backend, "vit-tiny/init", &mut vals);
        let task = crate::data::images::ImageTask::cifar_like(10, 8, 3, 0.25, 3);
        let mut cursor = 0u64;
        let (images, labels) = task.fill_flat(4, 0, &mut cursor, 3);
        vals.insert(
            "batch/images".to_string(),
            tensor_f32(&[4, 8, 8, 3], &images).unwrap(),
        );
        vals.insert(
            "batch/labels".to_string(),
            tensor_i32(&[4], &labels).unwrap(),
        );
        vals.insert("lr".to_string(), scalar_f32(0.01));
        let info = manifest.executable("vit-tiny/step_adam").unwrap();
        for t in &info.inputs {
            if t.name.starts_with("opt/") {
                vals.insert(t.name.clone(), zeros_for(t).unwrap());
            }
        }
        let mut losses = Vec::new();
        for s in 0..30 {
            vals.insert("step".to_string(), scalar_f32(s as f32));
            losses.push(
                run_named(&manifest, &backend, "vit-tiny/step_adam", &mut vals)
                    .unwrap(),
            );
        }
        assert!(losses.iter().all(|l| l.is_finite()), "{losses:?}");
        assert!(
            *losses.last().unwrap() < losses[0] - 0.2,
            "no descent: {losses:?}"
        );
        let loss = run_named(&manifest, &backend, "vit-tiny/eval", &mut vals);
        assert!(loss.unwrap().is_finite());
        let preds = vals.get("preds").unwrap().to_i32_vec().unwrap();
        assert_eq!(preds.len(), 4);
        assert!(preds.iter().all(|&p| (0..10).contains(&p)));
    }

    #[test]
    fn update_flora_adafactor_keeps_factored_state_shapes() {
        let (_, backend) = catalog();
        let update = exec(&backend, "lm-tiny/update_flora_r4_adafactor");
        let params = tensor_f32(&[64, 64], &[0.05; 64 * 64]).unwrap();
        let acc = tensor_f32(&[64, 4], &[0.5; 64 * 4]).unwrap();
        let vr = tensor_f32(&[64, 1], &[0.0; 64]).unwrap();
        let vc = tensor_f32(&[1, 64], &[0.0; 64]).unwrap();
        let outs = update
            .run(&[
                params.clone(),
                acc,
                vr,
                vc,
                scalar_f32(0.1),
                scalar_f32(0.0),
                scalar_u32(3),
                scalar_f32(4.0),
            ])
            .unwrap();
        assert_eq!(outs.len(), 3);
        assert_ne!(outs[0], params, "params did not move");
        assert_eq!(outs[1].shape(), &[64, 1]);
        assert_eq!(outs[2].shape(), &[1, 64]);
        // the factored moments absorbed the gradient energy
        assert!(outs[1].to_f32_vec().unwrap().iter().all(|&x| x >= 0.0));
        assert!(outs[1].to_f32_vec().unwrap().iter().any(|&x| x > 0.0));
    }

    #[test]
    fn adarank_full_rank_step_matches_flora_momentum() {
        let (manifest, backend) = catalog();
        let (toks, mask) = toy_batch(64, 16);
        let run = |name: &str, extra: &[(&str, Tensor)]| {
            let mut vals = BTreeMap::new();
            vals.insert("seed".to_string(), scalar_u32(5));
            run_named(&manifest, &backend, "lora-tiny/init", &mut vals);
            vals.insert("batch/tokens".to_string(), toks.clone());
            vals.insert("batch/mask".to_string(), mask.clone());
            vals.insert("lr".to_string(), scalar_f32(0.1));
            vals.insert("step".to_string(), scalar_f32(0.0));
            vals.insert("seed_cur".to_string(), scalar_u32(21));
            vals.insert("seed_next".to_string(), scalar_u32(22));
            vals.insert("resample".to_string(), scalar_f32(0.0));
            for (k, v) in extra {
                vals.insert((*k).to_string(), v.clone());
            }
            let info = manifest.executable(name).unwrap();
            for t in &info.inputs {
                if t.name.starts_with("mom/") {
                    vals.insert(t.name.clone(), zeros_for(t).unwrap());
                }
            }
            run_named(&manifest, &backend, name, &mut vals);
            vals
        };
        let flora = run("lora-tiny/mom_step_flora_r4_sgd", &[]);
        let ada = run(
            "lora-tiny/mom_step_r4_sgd_adarank",
            &[
                ("rank_cur", scalar_f32(4.0)),
                ("rank_next", scalar_f32(4.0)),
            ],
        );
        // at full rank the ranked step IS Algorithm 2, bit for bit
        for (k, v) in &flora {
            if k.starts_with("params/") || k.starts_with("mom/") {
                assert_eq!(Some(v), ada.get(k), "mismatch at {k}");
            }
        }
    }

    #[test]
    fn adarank_rejects_invalid_rank_scalars() {
        let (manifest, backend) = catalog();
        let mut vals = BTreeMap::new();
        vals.insert("seed".to_string(), scalar_u32(5));
        run_named(&manifest, &backend, "lora-tiny/init", &mut vals);
        let (toks, mask) = toy_batch(64, 16);
        let name = "lora-tiny/mom_step_r4_sgd_adarank";
        let info = manifest.executable(name).unwrap();
        vals.insert("batch/tokens".to_string(), toks);
        vals.insert("batch/mask".to_string(), mask);
        vals.insert("lr".to_string(), scalar_f32(0.1));
        vals.insert("step".to_string(), scalar_f32(0.0));
        vals.insert("seed_cur".to_string(), scalar_u32(1));
        vals.insert("seed_next".to_string(), scalar_u32(2));
        vals.insert("resample".to_string(), scalar_f32(0.0));
        vals.insert("rank_cur".to_string(), scalar_f32(8.0)); // > master 4
        vals.insert("rank_next".to_string(), scalar_f32(8.0));
        for t in &info.inputs {
            if t.name.starts_with("mom/") {
                vals.insert(t.name.clone(), zeros_for(t).unwrap());
            }
        }
        let inputs: Vec<Tensor> = info
            .inputs
            .iter()
            .map(|t| vals.get(&t.name).unwrap().clone())
            .collect();
        let err = exec(&backend, name).run(&inputs).err().expect("accepted");
        assert!(err.contains("master rank 4"), "{err}");
    }

    #[test]
    fn altlora_micro_then_update_reconstructs_and_moves_params() {
        let (manifest, backend) = catalog();
        let mut vals = BTreeMap::new();
        vals.insert("seed".to_string(), scalar_u32(3));
        run_named(&manifest, &backend, "lora-tiny/init", &mut vals);
        let (toks, mask) = toy_batch(64, 16);
        vals.insert("batch/tokens".to_string(), toks);
        vals.insert("batch/mask".to_string(), mask);
        let micro = manifest.executable("lora-tiny/micro_r4_altlora").unwrap();
        for t in &micro.inputs {
            if t.name.starts_with("acc/") || t.name.starts_with("ralt/") {
                vals.insert(t.name.clone(), zeros_for(t).unwrap());
            }
        }
        vals.insert("seed".to_string(), scalar_u32(40)); // cycle seed
        let loss = run_named(
            &manifest,
            &backend,
            "lora-tiny/micro_r4_altlora",
            &mut vals,
        )
        .unwrap();
        assert!(loss.is_finite());
        // both sketches picked up gradient mass on a projectable param...
        let acc = vals
            .get("acc/layer0/attn/wq")
            .unwrap()
            .to_f32_vec()
            .unwrap();
        assert!(acc.iter().any(|&x| x != 0.0));
        let ralt = vals
            .get("ralt/layer0/attn/wq")
            .unwrap()
            .to_f32_vec()
            .unwrap();
        assert!(ralt.iter().any(|&x| x != 0.0));
        // ...and there is NO left sketch for naive-procedure params
        assert!(!vals.contains_key("ralt/embed/tok"));
        let before = vals.get("params/layer0/attn/wq").unwrap().clone();
        vals.insert("lr".to_string(), scalar_f32(0.1));
        vals.insert("step".to_string(), scalar_f32(0.0));
        vals.insert("tau".to_string(), scalar_f32(1.0));
        run_named(
            &manifest,
            &backend,
            "lora-tiny/update_r4_sgd_altlora",
            &mut vals,
        );
        assert_ne!(vals.get("params/layer0/attn/wq").unwrap(), &before);
    }

    #[test]
    fn vit_altlora_step_runs_and_descends() {
        let (manifest, backend) = catalog();
        let mut vals = BTreeMap::new();
        vals.insert("seed".to_string(), scalar_u32(1));
        run_named(&manifest, &backend, "vit-tiny/init", &mut vals);
        let task = crate::data::images::ImageTask::cifar_like(10, 8, 3, 0.25, 3);
        let mut cursor = 0u64;
        let (images, labels) = task.fill_flat(4, 0, &mut cursor, 3);
        vals.insert(
            "batch/images".to_string(),
            tensor_f32(&[4, 8, 8, 3], &images).unwrap(),
        );
        vals.insert(
            "batch/labels".to_string(),
            tensor_i32(&[4], &labels).unwrap(),
        );
        vals.insert("lr".to_string(), scalar_f32(0.01));
        vals.insert("seed_cur".to_string(), scalar_u32(9));
        let name = "vit-tiny/step_r4_adam_altlora";
        let info = manifest.executable(name).unwrap();
        for t in &info.inputs {
            if t.name.starts_with("opt/") {
                vals.insert(t.name.clone(), zeros_for(t).unwrap());
            }
        }
        let mut losses = Vec::new();
        for s in 0..30 {
            vals.insert("step".to_string(), scalar_f32(s as f32));
            losses.push(run_named(&manifest, &backend, name, &mut vals).unwrap());
        }
        assert!(losses.iter().all(|l| l.is_finite()), "{losses:?}");
        assert!(
            *losses.last().unwrap() < losses[0] - 0.05,
            "no descent: {losses:?}"
        );
    }
}
