//! Native execution backend: a pure-rust executor for a generated catalog
//! of executables implementing the manifest ABI's fused steps — plain
//! steps, Algorithm-1 accumulation (micro + cycle-end update), Algorithm-2
//! momentum with κ-interval subspace transfer, and the GaLore
//! refresh-projection baseline — directly on `tensor::Matrix` with ALL
//! optimizer math delegated to the shared [`crate::opt`] layer
//! ([`BaseOptimizer`] + [`FloraCompressor`]). Adding a base optimizer is
//! one trait impl plus one [`OptimizerKind`] variant; the catalog then
//! grows its `*_{optimizer}` step names automatically.
//!
//! The native model is a seeded BIGRAM language model: the parameters are a
//! single `[vocab, vocab]` next-token logit table trained with masked
//! softmax cross-entropy. Deliberately the smallest model with a 2-D
//! gradient, because FLORA's subject is the *gradient pipeline*: G ∈
//! R^{v×v} flows through exactly the same compress/accumulate/decompress/
//! transfer algebra as the transformer gradients on the AOT path, and the
//! coordinator above cannot tell the difference — it sees the same
//! manifest groups, scalars and executable names.
//!
//! Deviations from the AOT catalog, by design:
//!   * the GaLore refresh regenerates the STORED projection from the seed
//!     (a JL subspace) instead of an SVD of the gradient; the memory and
//!     scheduling semantics the coordinator exercises (P lives in state,
//!     moments live in the subspace, refresh every κ steps) are identical.
//!   * no LoRA or ViT entries — those need the transformer/AOT path.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::rc::Rc;

use super::backend::{Backend, BackendExec};
use super::manifest::{ExecutableInfo, Manifest, ModelInfo, TensorSpec};
use super::values::{scalar_f32, Tensor};
use crate::opt::{Adam, BaseOptimizer, FloraCompressor, OptimizerKind, SubspaceTick, MOMENTUM_BETA};
use crate::rp;
use crate::tensor::Matrix;
use crate::util::rng::Rng;

/// Init scale of the logit table (small ⇒ near-uniform initial loss ln v).
const INIT_SIGMA: f32 = 0.05;
/// Ranks the generated catalog covers — a dense-enough grid for the bench
/// rank sweeps; the manifest is generated, so extending this is one edit.
const RANKS: [usize; 7] = [1, 2, 4, 8, 16, 32, 64];
/// Batch dimension advertised in the generated specs. The executor reads
/// the REAL batch from the input tensors at run time; the spec value only
/// matters to readers that size buffers from the manifest (greedy eval).
const SPEC_BATCH: usize = 4;
/// (name, vocab, seq_len) of the native model grid; vocab doubles as the
/// side of the logit table.
const MODELS: [(&str, usize, usize); 3] =
    [("lm-tiny", 64, 32), ("lm-small", 256, 64), ("lm-base", 512, 64)];

/// Which fused step a native executable performs. Update-bearing steps
/// carry the [`OptimizerKind`] whose [`crate::opt::BaseOptimizer`] does
/// the actual math.
#[derive(Clone, Copy, Debug)]
enum Step {
    Init,
    Eval,
    Greedy,
    Plain { opt: OptimizerKind },
    MicroFlora { rank: usize },
    MicroNaive,
    UpdateFlora { rank: usize, opt: OptimizerKind },
    UpdateNaive { opt: OptimizerKind },
    MomFlora { rank: usize, transfer: bool, opt: OptimizerKind },
    MomNaive { opt: OptimizerKind },
    GaloreStep { rank: usize },
}

/// One natively-executable catalog entry. Keeps its input specs so the
/// executor can route inputs by ABI name, mirroring the coordinator side.
struct NativeExec {
    name: String,
    vocab: usize,
    step: Step,
    inputs: Vec<TensorSpec>,
}

/// The native engine: executables are prepared at catalog build time, so
/// "compiling" is a map lookup.
pub struct NativeBackend {
    execs: BTreeMap<String, Rc<NativeExec>>,
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn compile(
        &mut self,
        info: &ExecutableInfo,
    ) -> Result<Rc<dyn BackendExec>, String> {
        let e = self.execs.get(&info.name).ok_or_else(|| {
            format!(
                "{}: not a native executable (the native catalog covers lm \
                 models with sgd/adam/adafactor steps at ranks {RANKS:?})",
                info.name
            )
        })?;
        Ok(e.clone() as Rc<dyn BackendExec>)
    }
}

/// The generated manifest alone (CLI `inspect --backend native`).
pub fn native_manifest() -> Manifest {
    catalog().0
}

/// Build the native catalog: the manifest the coordinator consumes plus
/// the backend that executes it. Both come from one generator so the ABI
/// (names, input/output order, shapes) cannot drift between them.
pub fn catalog() -> (Manifest, NativeBackend) {
    let mut models = BTreeMap::new();
    let mut executables = BTreeMap::new();
    let mut execs = BTreeMap::new();

    for (model, vocab, seq_len) in MODELS {
        let mut fields = BTreeMap::new();
        fields.insert("vocab".to_string(), vocab as f64);
        fields.insert("seq_len".to_string(), seq_len as f64);
        fields.insert("d_model".to_string(), vocab as f64);
        fields.insert("n_layers".to_string(), 1.0);
        models.insert(
            model.to_string(),
            ModelInfo { name: model.to_string(), kind: "lm".into(), fields },
        );

        let v = vocab;
        let s = seq_len;
        let b = SPEC_BATCH;
        let params = f32s("params/w", &[v, v]);
        let tokens = spec("batch/tokens", &[b, s], "int32");
        let mask = f32s("batch/mask", &[b, s]);
        let loss = f32s("loss", &[]);
        let lr = f32s("lr", &[]);
        let step_s = f32s("step", &[]);
        let seed = spec("seed", &[], "uint32");
        let acc_full = f32s("acc/w", &[v, v]);
        let mom_full = f32s("mom/w", &[v, v]);

        register(
            &mut executables,
            &mut execs,
            model,
            v,
            format!("{model}/init"),
            Step::Init,
            vec![seed.clone()],
            vec![params.clone()],
        );
        register(
            &mut executables,
            &mut execs,
            model,
            v,
            format!("{model}/eval"),
            Step::Eval,
            vec![params.clone(), tokens.clone(), mask.clone()],
            vec![loss.clone()],
        );
        register(
            &mut executables,
            &mut execs,
            model,
            v,
            format!("{model}/greedy"),
            Step::Greedy,
            vec![
                params.clone(),
                tokens.clone(),
                spec("prompt_len", &[], "int32"),
            ],
            vec![spec("tokens", &[b, s], "int32")],
        );

        // Algorithm-1 micro steps accumulate only — no optimizer involved,
        // so one entry each regardless of the base optimizer.
        register(
            &mut executables,
            &mut execs,
            model,
            v,
            format!("{model}/micro_naive"),
            Step::MicroNaive,
            vec![
                params.clone(),
                acc_full.clone(),
                tokens.clone(),
                mask.clone(),
                seed.clone(),
            ],
            vec![loss.clone(), acc_full.clone()],
        );
        for r in RANKS {
            if r > v {
                continue;
            }
            let acc = f32s("acc/w", &[v, r]);
            register(
                &mut executables,
                &mut execs,
                model,
                v,
                format!("{model}/micro_flora_r{r}"),
                Step::MicroFlora { rank: r },
                vec![
                    params.clone(),
                    acc.clone(),
                    tokens.clone(),
                    mask.clone(),
                    seed.clone(),
                ],
                vec![loss.clone(), acc],
            );
        }

        // Update-bearing steps: one set per base optimizer, with that
        // optimizer's state tensors spliced into the ABI as `opt/{slot}/w`.
        for opt in OptimizerKind::ALL {
            let opt_specs: Vec<TensorSpec> = opt
                .build()
                .state_shapes(v, v)
                .iter()
                .map(|(slot, sh)| f32s(&format!("opt/{slot}/w"), &sh[..]))
                .collect();
            let o = opt.name();

            register(
                &mut executables,
                &mut execs,
                model,
                v,
                format!("{model}/plain_step_{o}"),
                Step::Plain { opt },
                splice(
                    vec![params.clone()],
                    &opt_specs,
                    vec![tokens.clone(), mask.clone(), lr.clone(), step_s.clone()],
                ),
                splice(vec![loss.clone(), params.clone()], &opt_specs, vec![]),
            );
            register(
                &mut executables,
                &mut execs,
                model,
                v,
                format!("{model}/update_naive_{o}"),
                Step::UpdateNaive { opt },
                splice(
                    vec![params.clone(), acc_full.clone()],
                    &opt_specs,
                    vec![lr.clone(), step_s.clone(), seed.clone(), f32s("tau", &[])],
                ),
                splice(vec![params.clone()], &opt_specs, vec![]),
            );
            register(
                &mut executables,
                &mut execs,
                model,
                v,
                format!("{model}/mom_step_naive_{o}"),
                Step::MomNaive { opt },
                splice(
                    vec![params.clone(), mom_full.clone()],
                    &opt_specs,
                    vec![tokens.clone(), mask.clone(), lr.clone(), step_s.clone()],
                ),
                splice(
                    vec![loss.clone(), params.clone(), mom_full.clone()],
                    &opt_specs,
                    vec![],
                ),
            );

            for r in RANKS {
                if r > v {
                    continue;
                }
                let acc = f32s("acc/w", &[v, r]);
                let mom = f32s("mom/w", &[v, r]);
                register(
                    &mut executables,
                    &mut execs,
                    model,
                    v,
                    format!("{model}/update_flora_r{r}_{o}"),
                    Step::UpdateFlora { rank: r, opt },
                    splice(
                        vec![params.clone(), acc],
                        &opt_specs,
                        vec![
                            lr.clone(),
                            step_s.clone(),
                            seed.clone(),
                            f32s("tau", &[]),
                        ],
                    ),
                    splice(vec![params.clone()], &opt_specs, vec![]),
                );
                let mom_inputs = splice(
                    vec![params.clone(), mom.clone()],
                    &opt_specs,
                    vec![
                        tokens.clone(),
                        mask.clone(),
                        lr.clone(),
                        step_s.clone(),
                        spec("seed_cur", &[], "uint32"),
                        spec("seed_next", &[], "uint32"),
                        f32s("resample", &[]),
                    ],
                );
                let mom_outputs = splice(
                    vec![loss.clone(), params.clone(), mom.clone()],
                    &opt_specs,
                    vec![],
                );
                register(
                    &mut executables,
                    &mut execs,
                    model,
                    v,
                    format!("{model}/mom_step_flora_r{r}_{o}"),
                    Step::MomFlora { rank: r, transfer: true, opt },
                    mom_inputs.clone(),
                    mom_outputs.clone(),
                );
                register(
                    &mut executables,
                    &mut execs,
                    model,
                    v,
                    format!("{model}/mom_step_flora_notransfer_r{r}_{o}"),
                    Step::MomFlora { rank: r, transfer: false, opt },
                    mom_inputs,
                    mom_outputs,
                );
            }
        }

        // GaLore baseline: Adam-in-subspace with a stored projection and
        // κ-interval refresh; its moments are method state, not opt state.
        for r in RANKS {
            if r > v {
                continue;
            }
            register(
                &mut executables,
                &mut execs,
                model,
                v,
                format!("{model}/galore_step_r{r}"),
                Step::GaloreStep { rank: r },
                vec![
                    params.clone(),
                    f32s("m/w", &[v, r]),
                    f32s("proj/w", &[r, v]),
                    f32s("v/w", &[v, r]),
                    tokens.clone(),
                    mask.clone(),
                    lr.clone(),
                    step_s.clone(),
                    seed.clone(),
                    f32s("refresh", &[]),
                ],
                vec![
                    loss.clone(),
                    params.clone(),
                    f32s("m/w", &[v, r]),
                    f32s("proj/w", &[r, v]),
                    f32s("v/w", &[v, r]),
                ],
            );
        }
    }

    let manifest =
        Manifest { dir: PathBuf::from("native"), executables, models };
    (manifest, NativeBackend { execs })
}

fn spec(name: &str, shape: &[usize], dtype: &str) -> TensorSpec {
    TensorSpec {
        name: name.to_string(),
        shape: shape.to_vec(),
        dtype: dtype.to_string(),
    }
}

fn f32s(name: &str, shape: &[usize]) -> TensorSpec {
    spec(name, shape, "float32")
}

/// `head ++ mid ++ tail` — splices optimizer state specs into an ABI list.
fn splice(
    mut head: Vec<TensorSpec>,
    mid: &[TensorSpec],
    tail: Vec<TensorSpec>,
) -> Vec<TensorSpec> {
    head.extend(mid.iter().cloned());
    head.extend(tail);
    head
}

#[allow(clippy::too_many_arguments)]
fn register(
    executables: &mut BTreeMap<String, ExecutableInfo>,
    execs: &mut BTreeMap<String, Rc<NativeExec>>,
    model: &str,
    vocab: usize,
    name: String,
    step: Step,
    inputs: Vec<TensorSpec>,
    outputs: Vec<TensorSpec>,
) {
    executables.insert(
        name.clone(),
        ExecutableInfo {
            name: name.clone(),
            file: PathBuf::from("native"),
            model: model.to_string(),
            inputs: inputs.clone(),
            outputs,
        },
    );
    execs.insert(name.clone(), Rc::new(NativeExec { name, vocab, step, inputs }));
}

// ---------------------------------------------------------------------
// execution
// ---------------------------------------------------------------------

/// Borrowed view of an LM batch (tokens + loss mask).
struct BatchRef<'a> {
    tokens: &'a [i32],
    mask: &'a [f32],
    rows: usize,
    seq: usize,
}

fn batch_of<'a>(
    tokens: &'a Tensor,
    mask: &'a Tensor,
    ctx: &str,
) -> Result<BatchRef<'a>, String> {
    let (tshape, tdata) = match tokens {
        Tensor::I32 { shape, data } if shape.len() == 2 => (shape, data),
        _ => return Err(format!("{ctx}: batch/tokens must be 2-D int32")),
    };
    let mdata = mask.as_f32().map_err(|e| format!("{ctx}: batch/mask: {e}"))?;
    if mdata.len() != tdata.len() {
        return Err(format!("{ctx}: mask/tokens length mismatch"));
    }
    Ok(BatchRef {
        tokens: tdata,
        mask: mdata,
        rows: tshape[0],
        seq: tshape[1],
    })
}

fn matrix_of(t: &Tensor, ctx: &str) -> Result<Matrix, String> {
    match t {
        Tensor::F32 { shape, data } if shape.len() == 2 => {
            Ok(Matrix::from_vec(shape[0], shape[1], data.clone()))
        }
        other => Err(format!(
            "{ctx}: expected 2-D float32 tensor, got {:?} {}",
            other.shape(),
            other.dtype()
        )),
    }
}

fn tensor_of(m: Matrix) -> Tensor {
    Tensor::F32 { shape: vec![m.rows, m.cols], data: m.data }
}

/// Name-routed view of one invocation's inputs — the executor-side mirror
/// of the coordinator's `StepIo`, so neither side depends on positions.
struct Inputs<'a> {
    specs: &'a [TensorSpec],
    vals: &'a [Tensor],
    ctx: &'a str,
}

impl<'a> Inputs<'a> {
    fn get(&self, name: &str) -> Result<&'a Tensor, String> {
        self.specs
            .iter()
            .position(|s| s.name == name)
            .and_then(|i| self.vals.get(i))
            .ok_or_else(|| format!("{}: missing input {name:?}", self.ctx))
    }

    fn matrix(&self, name: &str) -> Result<Matrix, String> {
        matrix_of(self.get(name)?, self.ctx)
    }

    fn num(&self, name: &str) -> Result<f32, String> {
        self.get(name)?
            .first_f32()
            .map_err(|e| format!("{}: {name}: {e}", self.ctx))
    }

    fn useed(&self, name: &str) -> Result<u64, String> {
        self.get(name)?
            .first_u32()
            .map(|v| v as u64)
            .map_err(|e| format!("{}: {name}: {e}", self.ctx))
    }

    fn batch(&self) -> Result<BatchRef<'a>, String> {
        batch_of(self.get("batch/tokens")?, self.get("batch/mask")?, self.ctx)
    }

    /// All `opt/...` state tensors in declared (state_shapes) order.
    fn opt_state(&self) -> Result<Vec<Matrix>, String> {
        self.specs
            .iter()
            .zip(self.vals.iter())
            .filter(|(s, _)| s.name.starts_with("opt/"))
            .map(|(s, v)| {
                matrix_of(v, self.ctx)
                    .map_err(|e| format!("{} ({}): {e}", self.ctx, s.name))
            })
            .collect()
    }
}

/// Masked next-token cross-entropy of the bigram logit table, plus
/// (optionally) its gradient dL/dW. Both are normalized by the total mask
/// weight, mirroring the AOT step functions.
fn loss_and_grad(
    w: &Matrix,
    batch: &BatchRef<'_>,
    want_grad: bool,
    ctx: &str,
) -> Result<(f32, Matrix), String> {
    let v = w.cols;
    // eval paths (want_grad=false) skip the [v, v] gradient allocation —
    // at lm-base scale that is 1 MiB zeroed per eval batch otherwise
    let mut grad = if want_grad {
        Matrix::zeros(w.rows, w.cols)
    } else {
        Matrix::zeros(0, 0)
    };
    let mut total_w = 0.0f64;
    let mut total_loss = 0.0f64;
    let mut expd = vec![0.0f32; v];
    for row in 0..batch.rows {
        for i in 1..batch.seq {
            let wt = batch.mask[row * batch.seq + i];
            if wt <= 0.0 {
                continue;
            }
            let prev = batch.tokens[row * batch.seq + i - 1];
            let tgt = batch.tokens[row * batch.seq + i];
            if prev < 0 || prev as usize >= v || tgt < 0 || tgt as usize >= v
            {
                return Err(format!(
                    "{ctx}: token id out of range for vocab {v} \
                     (prev={prev} tgt={tgt})"
                ));
            }
            let (prev, tgt) = (prev as usize, tgt as usize);
            let logits = w.row(prev);
            let mx =
                logits.iter().fold(f32::NEG_INFINITY, |m, &x| m.max(x));
            let mut denom = 0.0f32;
            for (e, &x) in expd.iter_mut().zip(logits.iter()) {
                *e = (x - mx).exp();
                denom += *e;
            }
            total_loss +=
                wt as f64 * (denom.ln() + mx - logits[tgt]) as f64;
            total_w += wt as f64;
            if want_grad {
                for j in 0..v {
                    let p = expd[j] / denom;
                    let delta = if j == tgt { p - 1.0 } else { p };
                    *grad.at_mut(prev, j) += wt * delta;
                }
            }
        }
    }
    if total_w <= 0.0 {
        return Ok((0.0, grad));
    }
    let inv = (1.0 / total_w) as f32;
    if want_grad {
        for x in grad.data.iter_mut() {
            *x *= inv;
        }
    }
    Ok(((total_loss / total_w) as f32, grad))
}

/// `[head..., opt_state...]` — the standard output layout of an
/// update-bearing step.
fn outputs_with_state(head: Vec<Tensor>, state: Vec<Matrix>) -> Vec<Tensor> {
    let mut out = head;
    out.extend(state.into_iter().map(tensor_of));
    out
}

impl BackendExec for NativeExec {
    fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>, String> {
        let ctx = self.name.as_str();
        let ins = Inputs { specs: &self.inputs, vals: inputs, ctx };
        match self.step {
            Step::Init => {
                let seed = ins.useed("seed")?;
                let mut rng = Rng::new(seed);
                let w = Matrix::gaussian(
                    self.vocab,
                    self.vocab,
                    INIT_SIGMA,
                    &mut rng,
                );
                Ok(vec![tensor_of(w)])
            }
            Step::Eval => {
                let w = ins.matrix("params/w")?;
                let batch = ins.batch()?;
                let (loss, _) = loss_and_grad(&w, &batch, false, ctx)?;
                Ok(vec![scalar_f32(loss)])
            }
            Step::Greedy => {
                let w = ins.matrix("params/w")?;
                let (rows, s, mut out) = match ins.get("batch/tokens")? {
                    Tensor::I32 { shape, data } if shape.len() == 2 => {
                        (shape[0], shape[1], data.clone())
                    }
                    _ => {
                        return Err(format!(
                            "{ctx}: batch/tokens must be 2-D int32"
                        ))
                    }
                };
                let plen = ins
                    .get("prompt_len")?
                    .first_i32()
                    .map_err(|e| format!("{ctx}: prompt_len: {e}"))?
                    .max(1) as usize;
                for b in 0..rows {
                    for i in plen..s {
                        let prev = out[b * s + i - 1];
                        if prev < 0 || prev as usize >= self.vocab {
                            return Err(format!(
                                "{ctx}: prompt token {prev} out of range"
                            ));
                        }
                        let logits = w.row(prev as usize);
                        let mut best = 0usize;
                        for (j, &x) in logits.iter().enumerate() {
                            if x > logits[best] {
                                best = j;
                            }
                        }
                        out[b * s + i] = best as i32;
                    }
                }
                Ok(vec![Tensor::I32 { shape: vec![rows, s], data: out }])
            }
            Step::Plain { opt } => {
                let mut w = ins.matrix("params/w")?;
                let mut st = ins.opt_state()?;
                let batch = ins.batch()?;
                let lr = ins.num("lr")?;
                let step = ins.num("step")?;
                let (loss, g) = loss_and_grad(&w, &batch, true, ctx)?;
                opt.build()
                    .update(&mut w, &g, &mut st, lr, step)
                    .map_err(|e| format!("{ctx}: {e}"))?;
                Ok(outputs_with_state(vec![scalar_f32(loss), tensor_of(w)], st))
            }
            Step::MicroFlora { rank } => {
                let w = ins.matrix("params/w")?;
                let mut acc = ins.matrix("acc/w")?;
                let batch = ins.batch()?;
                let seed = ins.useed("seed")?;
                let (loss, g) = loss_and_grad(&w, &batch, true, ctx)?;
                // Algorithm 1 line 9: C += G Aᵀ with the cycle's shared
                // seed (accumulation is base-optimizer-free).
                let comp = FloraCompressor::new(crate::opt::Sgd, rank);
                comp.accumulate(&mut acc, &g, seed);
                Ok(vec![scalar_f32(loss), tensor_of(acc)])
            }
            Step::MicroNaive => {
                let w = ins.matrix("params/w")?;
                let mut acc = ins.matrix("acc/w")?;
                let batch = ins.batch()?;
                let (loss, g) = loss_and_grad(&w, &batch, true, ctx)?;
                acc.add_scaled_inplace(&g, 1.0);
                Ok(vec![scalar_f32(loss), tensor_of(acc)])
            }
            Step::UpdateFlora { rank, opt } => {
                let mut w = ins.matrix("params/w")?;
                let acc = ins.matrix("acc/w")?;
                let mut st = ins.opt_state()?;
                let lr = ins.num("lr")?;
                let step = ins.num("step")?;
                let seed = ins.useed("seed")?;
                let tau = ins.num("tau")?;
                // Algorithm 1 cycle end: decompress the mean gradient with
                // the SAME seed the micros used, then base-optimizer step.
                let comp = FloraCompressor::new(opt.build(), rank);
                comp.apply_accumulated(&mut w, &acc, &mut st, seed, tau, lr, step)
                    .map_err(|e| format!("{ctx}: {e}"))?;
                Ok(outputs_with_state(vec![tensor_of(w)], st))
            }
            Step::UpdateNaive { opt } => {
                let mut w = ins.matrix("params/w")?;
                let acc = ins.matrix("acc/w")?;
                let mut st = ins.opt_state()?;
                let lr = ins.num("lr")?;
                let step = ins.num("step")?;
                let tau = ins.num("tau")?.max(1.0);
                let ghat = acc.scale(1.0 / tau);
                opt.build()
                    .update(&mut w, &ghat, &mut st, lr, step)
                    .map_err(|e| format!("{ctx}: {e}"))?;
                Ok(outputs_with_state(vec![tensor_of(w)], st))
            }
            Step::MomFlora { rank, transfer, opt } => {
                let mut w = ins.matrix("params/w")?;
                let mut mom = ins.matrix("mom/w")?;
                let mut st = ins.opt_state()?;
                let batch = ins.batch()?;
                let lr = ins.num("lr")?;
                let step = ins.num("step")?;
                let tick = SubspaceTick {
                    seed_cur: ins.useed("seed_cur")?,
                    seed_next: ins.useed("seed_next")?,
                    resample: ins.num("resample")? >= 0.5,
                    transfer,
                };
                let (loss, g) = loss_and_grad(&w, &batch, true, ctx)?;
                let comp = FloraCompressor::new(opt.build(), rank);
                comp.momentum_step(&mut w, &mut mom, &mut st, &g, tick, lr, step)
                    .map_err(|e| format!("{ctx}: {e}"))?;
                Ok(outputs_with_state(
                    vec![scalar_f32(loss), tensor_of(w), tensor_of(mom)],
                    st,
                ))
            }
            Step::MomNaive { opt } => {
                let mut w = ins.matrix("params/w")?;
                let mom = ins.matrix("mom/w")?;
                let mut st = ins.opt_state()?;
                let batch = ins.batch()?;
                let lr = ins.num("lr")?;
                let step = ins.num("step")?;
                let (loss, g) = loss_and_grad(&w, &batch, true, ctx)?;
                let mut new_mom = mom.scale(MOMENTUM_BETA);
                new_mom.add_scaled_inplace(&g, 1.0 - MOMENTUM_BETA);
                opt.build()
                    .update(&mut w, &new_mom, &mut st, lr, step)
                    .map_err(|e| format!("{ctx}: {e}"))?;
                Ok(outputs_with_state(
                    vec![scalar_f32(loss), tensor_of(w), tensor_of(new_mom)],
                    st,
                ))
            }
            Step::GaloreStep { rank } => {
                let mut w = ins.matrix("params/w")?;
                let mut m = ins.matrix("m/w")?;
                let p_in = ins.matrix("proj/w")?;
                let mut vv = ins.matrix("v/w")?;
                let batch = ins.batch()?;
                let lr = ins.num("lr")?;
                let step = ins.num("step")?;
                let seed = ins.useed("seed")?;
                let refresh = ins.num("refresh")? >= 0.5;
                // GaLore stores P (that's its memory cost); refresh swaps
                // it for a fresh seeded subspace every κ steps.
                let p = if refresh {
                    rp::projection(seed, rank, w.cols)
                } else {
                    p_in
                };
                let (loss, g) = loss_and_grad(&w, &batch, true, ctx)?;
                let c = rp::compress(&g, &p);
                // Adam-in-subspace: same moment/bias-correction rule as the
                // full Adam, applied to the compressed moments.
                let dir = Adam::new().direction(&mut m, &mut vv, &c, step);
                let upd = rp::decompress(&dir, &p);
                w.add_scaled_inplace(&upd, -lr);
                Ok(vec![
                    scalar_f32(loss),
                    tensor_of(w),
                    tensor_of(m),
                    tensor_of(p),
                    tensor_of(vv),
                ])
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::values::{scalar_f32, scalar_u32, tensor_f32};

    fn exec<'a>(backend: &'a NativeBackend, name: &str) -> &'a Rc<NativeExec> {
        backend.execs.get(name).unwrap()
    }

    fn toy_batch(v: usize, s: usize) -> (Tensor, Tensor) {
        // two rows: a repeating 5,6,7,... ramp with the tail masked in
        let rows = 2usize;
        let mut toks = vec![0i32; rows * s];
        let mut mask = vec![0.0f32; rows * s];
        for b in 0..rows {
            for i in 0..s {
                toks[b * s + i] = (5 + (b + i) % (v - 5)) as i32;
                if i >= s / 2 {
                    mask[b * s + i] = 1.0;
                }
            }
        }
        (
            Tensor::I32 { shape: vec![rows, s], data: toks },
            tensor_f32(&[rows, s], &mask).unwrap(),
        )
    }

    #[test]
    fn catalog_and_manifest_agree() {
        let (manifest, backend) = catalog();
        assert_eq!(manifest.executables.len(), backend.execs.len());
        for name in manifest.executables.keys() {
            assert!(backend.execs.contains_key(name), "missing exec {name}");
        }
        // ABI arity spot checks: the sgd names keep their PR-1 shape...
        let e = manifest.executable("lm-tiny/plain_step_sgd").unwrap();
        assert_eq!(e.inputs.len(), 5);
        assert_eq!(e.outputs.len(), 2);
        let e = manifest.executable("lm-tiny/galore_step_r8").unwrap();
        assert_eq!(e.inputs.len(), 10);
        assert_eq!(e.outputs.len(), 5);
        // ...and the adam/adafactor variants splice their opt state in.
        let e = manifest.executable("lm-tiny/plain_step_adam").unwrap();
        assert_eq!(e.inputs.len(), 7);
        assert_eq!(e.outputs.len(), 4);
        assert_eq!(e.inputs[1].name, "opt/m/w");
        assert_eq!(e.inputs[2].name, "opt/v/w");
        let e = manifest
            .executable("lm-tiny/update_flora_r8_adafactor")
            .unwrap();
        assert_eq!(e.inputs.len(), 8);
        assert_eq!(e.outputs.len(), 3);
        assert_eq!(e.inputs[2].name, "opt/vr/w");
        assert_eq!(e.inputs[2].shape, vec![64, 1]);
        assert_eq!(e.inputs[3].name, "opt/vc/w");
        assert_eq!(e.inputs[3].shape, vec![1, 64]);
        let e = manifest
            .executable("lm-tiny/mom_step_flora_r8_adam")
            .unwrap();
        assert_eq!(e.inputs.len(), 11);
        assert_eq!(e.outputs.len(), 5);
    }

    #[test]
    fn catalog_covers_every_optimizer() {
        let (manifest, _) = catalog();
        for opt in OptimizerKind::ALL {
            let o = opt.name();
            for exe in [
                format!("lm-tiny/plain_step_{o}"),
                format!("lm-tiny/update_naive_{o}"),
                format!("lm-tiny/update_flora_r8_{o}"),
                format!("lm-tiny/mom_step_naive_{o}"),
                format!("lm-tiny/mom_step_flora_r8_{o}"),
                format!("lm-tiny/mom_step_flora_notransfer_r8_{o}"),
            ] {
                assert!(
                    manifest.executables.contains_key(&exe),
                    "missing {exe}"
                );
            }
        }
    }

    #[test]
    fn init_is_deterministic_per_seed() {
        let (_, backend) = catalog();
        let init = exec(&backend, "lm-tiny/init");
        let a = init.run(&[scalar_u32(7)]).unwrap();
        let b = init.run(&[scalar_u32(7)]).unwrap();
        let c = init.run(&[scalar_u32(8)]).unwrap();
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert_eq!(a[0].element_count(), 64 * 64);
    }

    #[test]
    fn plain_step_descends_on_repeated_batch() {
        let (_, backend) = catalog();
        let init = exec(&backend, "lm-tiny/init");
        let step = exec(&backend, "lm-tiny/plain_step_sgd");
        let (toks, mask) = toy_batch(64, 32);
        let mut params = init.run(&[scalar_u32(0)]).unwrap().remove(0);
        let mut losses = Vec::new();
        for s in 0..30 {
            let outs = step
                .run(&[
                    params.clone(),
                    toks.clone(),
                    mask.clone(),
                    scalar_f32(0.5),
                    scalar_f32(s as f32),
                ])
                .unwrap();
            losses.push(outs[0].first_f32().unwrap());
            params = outs.into_iter().nth(1).unwrap();
        }
        let first = losses[0];
        let last = *losses.last().unwrap();
        assert!(first.is_finite() && last.is_finite());
        // init is near-uniform: loss ≈ ln 64; a fixed batch must overfit
        assert!((first - (64f32).ln()).abs() < 0.5, "first={first}");
        assert!(last < first - 0.5, "no descent: {first} -> {last}");
    }

    #[test]
    fn plain_step_adam_descends_and_threads_opt_state() {
        let (_, backend) = catalog();
        let init = exec(&backend, "lm-tiny/init");
        let step = exec(&backend, "lm-tiny/plain_step_adam");
        let (toks, mask) = toy_batch(64, 32);
        let mut params = init.run(&[scalar_u32(0)]).unwrap().remove(0);
        let zeros = tensor_f32(&[64, 64], &[0.0; 64 * 64]).unwrap();
        let (mut m, mut v) = (zeros.clone(), zeros);
        let mut losses = Vec::new();
        for s in 0..30 {
            let outs = step
                .run(&[
                    params.clone(),
                    m.clone(),
                    v.clone(),
                    toks.clone(),
                    mask.clone(),
                    scalar_f32(0.05),
                    scalar_f32(s as f32),
                ])
                .unwrap();
            losses.push(outs[0].first_f32().unwrap());
            let mut it = outs.into_iter();
            it.next(); // loss
            params = it.next().unwrap();
            m = it.next().unwrap();
            v = it.next().unwrap();
        }
        // the second moment must be strictly positive after 30 steps
        assert!(v.to_f32_vec().unwrap().iter().any(|&x| x > 0.0));
        let first = losses[0];
        let last = *losses.last().unwrap();
        assert!((first - (64f32).ln()).abs() < 0.5, "first={first}");
        assert!(last < first - 0.5, "no adam descent: {first} -> {last}");
    }

    #[test]
    fn plain_gradient_matches_finite_differences() {
        let (toks, mask) = toy_batch(64, 32);
        let batch = batch_of(&toks, &mask, "t").unwrap();
        let mut rng = Rng::new(3);
        let w = Matrix::gaussian(64, 64, 0.3, &mut rng);
        let (_, g) = loss_and_grad(&w, &batch, true, "t").unwrap();
        let eps = 1e-3f32;
        for &(i, j) in &[(5usize, 6usize), (6, 7), (9, 10)] {
            let mut wp = w.clone();
            *wp.at_mut(i, j) += eps;
            let mut wm = w.clone();
            *wm.at_mut(i, j) -= eps;
            let (lp, _) = loss_and_grad(&wp, &batch, false, "t").unwrap();
            let (lm, _) = loss_and_grad(&wm, &batch, false, "t").unwrap();
            let fd = (lp - lm) / (2.0 * eps);
            let an = g.at(i, j);
            assert!(
                (fd - an).abs() < 1e-2 * (1.0 + fd.abs().max(an.abs())),
                "({i},{j}): fd={fd} analytic={an}"
            );
        }
    }

    #[test]
    fn flora_micro_accumulates_compressed_gradient() {
        let (_, backend) = catalog();
        let init = exec(&backend, "lm-tiny/init");
        let micro = exec(&backend, "lm-tiny/micro_flora_r4");
        let (toks, mask) = toy_batch(64, 32);
        let params = init.run(&[scalar_u32(1)]).unwrap().remove(0);
        let zero_acc = tensor_f32(&[64, 4], &[0.0; 64 * 4]).unwrap();
        let outs = micro
            .run(&[
                params.clone(),
                zero_acc.clone(),
                toks.clone(),
                mask.clone(),
                scalar_u32(99),
            ])
            .unwrap();
        let acc1 = outs[1].to_f32_vec().unwrap();
        assert_eq!(acc1.len(), 64 * 4);
        assert!(acc1.iter().any(|&x| x != 0.0));
        // two identical micros accumulate to exactly twice one micro
        let outs2 = micro
            .run(&[params, outs[1].clone(), toks, mask, scalar_u32(99)])
            .unwrap();
        let acc2 = outs2[1].to_f32_vec().unwrap();
        for (a2, a1) in acc2.iter().zip(acc1.iter()) {
            assert!((a2 - 2.0 * a1).abs() < 1e-4, "{a2} vs 2*{a1}");
        }
    }

    #[test]
    fn momentum_transfer_fires_only_on_resample() {
        let (_, backend) = catalog();
        let init = exec(&backend, "lm-tiny/init");
        let step = exec(&backend, "lm-tiny/mom_step_flora_r4_sgd");
        let (toks, mask) = toy_batch(64, 32);
        let params = init.run(&[scalar_u32(2)]).unwrap().remove(0);
        let mom = tensor_f32(&[64, 4], &[0.1; 64 * 4]).unwrap();
        let base = vec![
            params,
            mom,
            toks,
            mask,
            scalar_f32(0.1),
            scalar_f32(0.0),
            scalar_u32(11),
            scalar_u32(12),
            scalar_f32(0.0),
        ];
        let quiet = step.run(&base).unwrap();
        let mut resampled_in = base.clone();
        resampled_in[8] = scalar_f32(1.0);
        let resampled = step.run(&resampled_in).unwrap();
        // the transfer rotates the momentum into a new subspace, so the
        // resulting EMA state must differ from the quiet step's
        assert_ne!(quiet[2], resampled[2]);
    }

    #[test]
    fn update_flora_adafactor_keeps_factored_state_shapes() {
        let (_, backend) = catalog();
        let update = exec(&backend, "lm-tiny/update_flora_r4_adafactor");
        let params = tensor_f32(&[64, 64], &[0.05; 64 * 64]).unwrap();
        let acc = tensor_f32(&[64, 4], &[0.5; 64 * 4]).unwrap();
        let vr = tensor_f32(&[64, 1], &[0.0; 64]).unwrap();
        let vc = tensor_f32(&[1, 64], &[0.0; 64]).unwrap();
        let outs = update
            .run(&[
                params.clone(),
                acc,
                vr,
                vc,
                scalar_f32(0.1),
                scalar_f32(0.0),
                scalar_u32(3),
                scalar_f32(4.0),
            ])
            .unwrap();
        assert_eq!(outs.len(), 3);
        assert_ne!(outs[0], params, "params did not move");
        assert_eq!(outs[1].shape(), &[64, 1]);
        assert_eq!(outs[2].shape(), &[1, 64]);
        // the factored moments absorbed the gradient energy
        assert!(outs[1].to_f32_vec().unwrap().iter().all(|&x| x >= 0.0));
        assert!(outs[1].to_f32_vec().unwrap().iter().any(|&x| x > 0.0));
    }
}
