//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt` +
//! `manifest.json`) and executes them on the CPU PJRT client via the `xla`
//! crate. This is the only module that touches XLA; everything above it
//! works with `Literal` groups described by the manifest.
//!
//! Interchange is HLO **text** — xla_extension 0.5.1 rejects jax≥0.5
//! serialized protos (64-bit instruction ids); the text parser reassigns
//! ids (see /opt/xla-example/README.md and DESIGN.md §8).

pub mod client;
pub mod manifest;
pub mod state;
pub mod values;

pub use client::{Executable, Runtime};
pub use manifest::{Manifest, ModelInfo, TensorSpec};
pub use state::StateStore;
pub use values::{
    literal_f32, literal_i32, literal_to_f32, scalar_f32, scalar_i32,
    scalar_u32,
};
