//! Execution runtime: backend-neutral `Tensor` values, the `Backend`
//! boundary, and a `Runtime` that resolves manifest executables through a
//! selected engine.
//!
//! Two backends implement the same manifest ABI:
//!
//!   * **native** (default, pure rust) — a generated catalog whose fused
//!     steps (plain, Algorithm-1 accumulation, Algorithm-2 momentum,
//!     GaLore refresh — each over every `crate::opt` base optimizer) run
//!     directly on `tensor::Matrix` + `crate::opt` + `rp`. No artifacts,
//!     no external libraries.
//!   * **pjrt** (`--features xla`) — loads the AOT artifacts
//!     (`artifacts/*.hlo.txt` + `manifest.json`) and executes them on the
//!     CPU PJRT client via the vendored `xla` crate. Interchange is HLO
//!     **text** — xla_extension 0.5.1 rejects jax≥0.5 serialized protos
//!     (64-bit instruction ids); the text parser reassigns ids (DESIGN.md
//!     §8).
//!
//! The **serving tier** ([`adapters`] + [`serve`]) also lives here: a
//! capacity-bounded LRU adapter registry and a dynamic batcher feeding
//! the KV-cache multi-adapter decode of `model::decode`, behind the
//! `flora serve` subcommand. `docs/SERVING.md` is the handbook.
//!
//! The **data-parallel tier** ([`dp`]) trains the native LM family with
//! Flora-compressed gradient exchange behind `flora train-dp`: workers
//! on the persistent kernel pool ship rank-r projected gradients into a
//! fixed-order reduce, bit-identical at every `--workers`, with a
//! [`CommsLedger`] accounting the O(rd)-vs-O(d²) bytes.
//! `docs/DISTRIBUTED.md` is the handbook.

pub mod adapters;
pub mod backend;
pub mod client;
pub mod dp;
pub mod manifest;
pub mod native;
#[cfg(feature = "xla")]
pub mod pjrt;
pub mod serve;
pub mod state;
pub mod values;

pub use adapters::{AdapterProvenance, AdapterRegistry, AdapterStats};
pub use backend::{Backend, BackendExec};
pub use dp::{CommsLedger, DpReport, DpTrainer, ReduceMode, ShardPlan};
pub use serve::{BatchPolicy, Batcher, Server, ServeRequest, ServeResponse};
pub use client::{Executable, Runtime};
pub use manifest::{Manifest, ModelInfo, TensorSpec};
pub use native::{catalog_summary, native_manifest, NativeBackend};
pub use state::StateStore;
pub use values::{
    scalar_f32, scalar_i32, scalar_u32, tensor_f32, tensor_i32, zeros_for,
    OutKind, Route, ScalarKey, StateGroup, StepIo, StepOutputs, Tensor,
};
