//! The serving tier's adapter registry: a capacity-bounded, LRU-evicting
//! cache of resident [`AdapterParams`], hot-loadable from training
//! checkpoints. The paper's observation that adapters are *seeded random
//! projections with tiny state* is what makes this registry cheap: a
//! rank-8 lora-base adapter is ~292 KiB resident, so hundreds fit where
//! one merged weight copy would live, and a miss costs one checkpoint
//! read plus a factor split — no base-weight traffic at all.
//!
//! Provenance is recorded per entry ([`AdapterProvenance`]): either the
//! checkpoint path the `train/` state group was restored from, or the
//! seed a synthetic (demo/bench) adapter was derived with — the
//! lifecycle contract `docs/SERVING.md` §2 documents.
//!
//! The registry pins one rank per process (first insert wins): the
//! batcher groups requests only by shape, and [`serve_greedy`]'s batched
//! `(x·B)·A` corrections need every panel's factors to share `[n, r]` /
//! `[r, m]` shapes. Mixed-rank fleets run as separate registries.
//!
//! [`serve_greedy`]: crate::model::decode::serve_greedy

use std::collections::BTreeMap;

use crate::coordinator::checkpoint::Checkpoint;
use crate::model::{AdapterParams, LoraAdapter, ParamSet, TransformerConfig};
use crate::tensor::Matrix;
use crate::util::rng::{derive_seed, Rng};

/// Where an adapter's state came from — kept with the entry so serving
/// responses and bench snapshots can be traced back to training runs.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum AdapterProvenance {
    /// Restored from the `train/` state group of this checkpoint file.
    Checkpoint(String),
    /// Synthesized in-process from this seed (demo and bench traffic).
    Synthetic { seed: u64 },
}

/// Lifecycle counters, reported by `flora serve` and the smoke tests.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct AdapterStats {
    pub loads: usize,
    pub evictions: usize,
    pub hits: usize,
    pub misses: usize,
}

struct Entry {
    params: AdapterParams,
    provenance: AdapterProvenance,
    last_used: u64,
}

/// Capacity-bounded LRU cache of resident adapters, keyed by name.
///
/// ```
/// use flora::model::TransformerConfig;
/// use flora::runtime::AdapterRegistry;
///
/// let cfg = TransformerConfig::tiny();
/// let base = cfg.init(0);
/// let mut reg = AdapterRegistry::new(2);
/// reg.insert_synthetic("alice", &cfg, &base, 4, 1).unwrap();
/// reg.insert_synthetic("bob", &cfg, &base, 4, 2).unwrap();
/// assert_eq!(reg.len(), 2);
/// assert_eq!(reg.rank(), Some(4));
///
/// // touching "alice" makes "bob" the LRU entry, so a third insert
/// // at capacity 2 evicts "bob"
/// assert!(reg.get("alice").is_some());
/// reg.insert_synthetic("carol", &cfg, &base, 4, 3).unwrap();
/// assert!(reg.get("bob").is_none());
/// assert!(reg.get("alice").is_some());
/// assert_eq!(reg.stats().evictions, 1);
/// ```
pub struct AdapterRegistry {
    capacity: usize,
    entries: BTreeMap<String, Entry>,
    rank: Option<usize>,
    tick: u64,
    stats: AdapterStats,
}

impl AdapterRegistry {
    /// A registry holding at most `capacity` resident adapters.
    pub fn new(capacity: usize) -> Self {
        assert!(capacity >= 1, "adapter registry capacity must be >= 1");
        Self {
            capacity,
            entries: BTreeMap::new(),
            rank: None,
            tick: 0,
            stats: AdapterStats::default(),
        }
    }

    /// Insert (or replace) an adapter, evicting the least-recently-used
    /// resident entry if the registry is at capacity. The first insert
    /// pins the registry's rank; later inserts must match it.
    pub fn insert(
        &mut self,
        name: &str,
        params: AdapterParams,
        provenance: AdapterProvenance,
    ) -> Result<(), String> {
        match self.rank {
            None => self.rank = Some(params.rank),
            Some(r) if r != params.rank => {
                return Err(format!(
                    "adapter {name:?} has rank {} but the registry serves rank {r}",
                    params.rank
                ))
            }
            _ => {}
        }
        if !self.entries.contains_key(name) && self.entries.len() >= self.capacity {
            if let Some(lru) = self
                .entries
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(n, _)| n.clone())
            {
                self.entries.remove(&lru);
                self.stats.evictions += 1;
            }
        }
        self.tick += 1;
        self.entries
            .insert(name.to_string(), Entry { params, provenance, last_used: self.tick });
        self.stats.loads += 1;
        Ok(())
    }

    /// Load an adapter from the `train/` state group of a training
    /// checkpoint (`Trainer::save_checkpoint`'s format). Returns the
    /// inferred rank.
    pub fn load_checkpoint(&mut self, name: &str, path: &str) -> Result<usize, String> {
        let ck = Checkpoint::load(path)?;
        let group = ck
            .groups
            .iter()
            .find(|g| g.name == "train")
            .ok_or_else(|| format!("checkpoint {path} has no `train` state group"))?;
        let mut train = ParamSet::new();
        for (spec, data) in &group.tensors {
            let key = spec.name.strip_prefix("train/").unwrap_or(&spec.name);
            let (rows, cols) = match spec.shape.len() {
                2 => (spec.shape[0], spec.shape[1]),
                1 => (1, spec.shape[0]),
                n => {
                    return Err(format!(
                        "checkpoint {path}: tensor {} has unsupported rank {n}",
                        spec.name
                    ))
                }
            };
            if rows * cols != data.len() {
                return Err(format!(
                    "checkpoint {path}: tensor {} shape/payload mismatch",
                    spec.name
                ));
            }
            train.insert(key.to_string(), Matrix::from_vec(rows, cols, data.clone()));
        }
        let params = AdapterParams::from_trainable(&train)?;
        let rank = params.rank;
        self.insert(name, params, AdapterProvenance::Checkpoint(path.to_string()))?;
        Ok(rank)
    }

    /// Insert a seeded synthetic adapter: `LoraAdapter::init_trainable`
    /// state with each `B` factor perturbed to a small Gaussian (a
    /// zero `B` would make every adapter serve base-model outputs).
    /// Demo and bench traffic only — real serving loads checkpoints.
    pub fn insert_synthetic(
        &mut self,
        name: &str,
        cfg: &TransformerConfig,
        base: &ParamSet,
        rank: usize,
        seed: u64,
    ) -> Result<(), String> {
        let ad = LoraAdapter::new(cfg.param_shapes(), rank);
        let mut train = ad.init_trainable(base, seed);
        let bnames: Vec<String> =
            train.keys().filter(|n| n.starts_with("lora_B/")).cloned().collect();
        for (i, bname) in bnames.iter().enumerate() {
            let m = train.get_mut(bname).unwrap();
            let mut rng = Rng::new(derive_seed(seed ^ 0x5e21, i as u64));
            rng.fill_gaussian(&mut m.data, 0.05);
        }
        let params = AdapterParams::from_trainable(&train)?;
        self.insert(name, params, AdapterProvenance::Synthetic { seed })
    }

    /// Fetch a resident adapter, marking it most-recently-used.
    pub fn get(&mut self, name: &str) -> Option<&AdapterParams> {
        if !self.entries.contains_key(name) {
            self.stats.misses += 1;
            return None;
        }
        self.tick += 1;
        self.stats.hits += 1;
        let e = self.entries.get_mut(name).unwrap();
        e.last_used = self.tick;
        Some(&self.entries[name].params)
    }

    /// Fetch one batch's adapters in request order (all marked used).
    /// Errors on the first non-resident name — the serve executor treats
    /// that as a routing bug, not a cache miss to absorb silently.
    pub fn get_many(&mut self, names: &[String]) -> Result<Vec<&AdapterParams>, String> {
        for n in names {
            if !self.entries.contains_key(n) {
                self.stats.misses += 1;
                return Err(format!("adapter {n:?} is not resident"));
            }
        }
        for n in names {
            self.tick += 1;
            self.stats.hits += 1;
            self.entries.get_mut(n).unwrap().last_used = self.tick;
        }
        Ok(names.iter().map(|n| &self.entries[n].params).collect())
    }

    pub fn contains(&self, name: &str) -> bool {
        self.entries.contains_key(name)
    }

    pub fn provenance(&self, name: &str) -> Option<&AdapterProvenance> {
        self.entries.get(name).map(|e| &e.provenance)
    }

    /// Resident adapter names, sorted.
    pub fn names(&self) -> Vec<String> {
        self.entries.keys().cloned().collect()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// The rank every resident adapter shares (None while empty).
    pub fn rank(&self) -> Option<usize> {
        self.rank
    }

    /// Total resident adapter state in bytes (f32 payload).
    pub fn state_bytes(&self) -> usize {
        self.entries.values().map(|e| e.params.state_bytes()).sum()
    }

    pub fn stats(&self) -> AdapterStats {
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_registry(capacity: usize) -> (TransformerConfig, ParamSet, AdapterRegistry) {
        let cfg = TransformerConfig::tiny();
        let base = cfg.init(0);
        (cfg, base, AdapterRegistry::new(capacity))
    }

    #[test]
    fn lru_eviction_follows_recency_not_insertion() {
        let (cfg, base, mut reg) = tiny_registry(2);
        reg.insert_synthetic("a", &cfg, &base, 4, 1).unwrap();
        reg.insert_synthetic("b", &cfg, &base, 4, 2).unwrap();
        assert!(reg.get("a").is_some()); // "b" is now LRU
        reg.insert_synthetic("c", &cfg, &base, 4, 3).unwrap();
        assert!(reg.contains("a") && reg.contains("c") && !reg.contains("b"));
        let st = reg.stats();
        assert_eq!((st.loads, st.evictions), (3, 1));
    }

    #[test]
    fn reinsert_does_not_evict() {
        let (cfg, base, mut reg) = tiny_registry(2);
        reg.insert_synthetic("a", &cfg, &base, 4, 1).unwrap();
        reg.insert_synthetic("b", &cfg, &base, 4, 2).unwrap();
        reg.insert_synthetic("a", &cfg, &base, 4, 9).unwrap(); // replace in place
        assert_eq!(reg.len(), 2);
        assert_eq!(reg.stats().evictions, 0);
        assert_eq!(reg.provenance("a"), Some(&AdapterProvenance::Synthetic { seed: 9 }));
    }

    #[test]
    fn rank_is_pinned_by_first_insert() {
        let (cfg, base, mut reg) = tiny_registry(4);
        reg.insert_synthetic("a", &cfg, &base, 4, 1).unwrap();
        let err = reg.insert_synthetic("b", &cfg, &base, 8, 2).unwrap_err();
        assert!(err.contains("rank"), "{err}");
        assert_eq!(reg.rank(), Some(4));
    }

    #[test]
    fn get_many_preserves_order_and_errors_on_missing() {
        let (cfg, base, mut reg) = tiny_registry(4);
        reg.insert_synthetic("a", &cfg, &base, 4, 1).unwrap();
        reg.insert_synthetic("b", &cfg, &base, 4, 2).unwrap();
        let names = vec!["b".to_string(), "a".to_string(), "b".to_string()];
        let got = reg.get_many(&names).unwrap();
        assert_eq!(got.len(), 3);
        assert!(std::ptr::eq(got[0], got[2]));
        assert!(reg.get_many(&["ghost".to_string()]).is_err());
        assert_eq!(reg.stats().misses, 1);
    }

    #[test]
    fn state_bytes_track_residency() {
        let (cfg, base, mut reg) = tiny_registry(4);
        assert_eq!(reg.state_bytes(), 0);
        reg.insert_synthetic("a", &cfg, &base, 4, 1).unwrap();
        let one = reg.state_bytes();
        assert!(one > 0);
        reg.insert_synthetic("b", &cfg, &base, 4, 2).unwrap();
        assert_eq!(reg.state_bytes(), 2 * one);
    }
}
