//! Backend-neutral tensor values + the typed ABI routing layer.
//!
//! `Tensor` replaces `xla::Literal` everywhere above the backend boundary:
//! the coordinator moves named `Tensor` groups between executables and
//! never touches backend-specific buffers. Backends convert at their edge
//! (the PJRT backend to `Literal`s, the native backend to `tensor::Matrix`).
//!
//! Every ABI tensor name classifies into exactly one [`Route`]: a state
//! group ([`StateGroup`]), a batch input, a typed scalar ([`ScalarKey`]),
//! or a step output ([`OutKind`]). [`StepIo`] assembles an executable's
//! input list from those routes, and [`StepOutputs`] routes the result
//! tuple back — by NAME, never by tuple position, so a catalog that
//! reorders or grows its state groups cannot silently mis-wire a step.

use std::collections::BTreeMap;

use super::manifest::{ExecutableInfo, TensorSpec};
use super::state::StateStore;

/// A host tensor in one of the three dtypes the manifest ABI uses.
#[derive(Clone, Debug, PartialEq)]
pub enum Tensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
    U32 { shape: Vec<usize>, data: Vec<u32> },
}

impl Tensor {
    pub fn shape(&self) -> &[usize] {
        match self {
            Tensor::F32 { shape, .. }
            | Tensor::I32 { shape, .. }
            | Tensor::U32 { shape, .. } => shape,
        }
    }

    pub fn element_count(&self) -> usize {
        match self {
            Tensor::F32 { data, .. } => data.len(),
            Tensor::I32 { data, .. } => data.len(),
            Tensor::U32 { data, .. } => data.len(),
        }
    }

    pub fn dtype(&self) -> &'static str {
        match self {
            Tensor::F32 { .. } => "float32",
            Tensor::I32 { .. } => "int32",
            Tensor::U32 { .. } => "uint32",
        }
    }

    pub fn byte_size(&self) -> usize {
        self.element_count() * 4
    }

    /// Borrow the f32 payload.
    pub fn as_f32(&self) -> Result<&[f32], String> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            other => {
                Err(format!("expected float32 tensor, got {}", other.dtype()))
            }
        }
    }

    /// Borrow the i32 payload.
    pub fn as_i32(&self) -> Result<&[i32], String> {
        match self {
            Tensor::I32 { data, .. } => Ok(data),
            other => {
                Err(format!("expected int32 tensor, got {}", other.dtype()))
            }
        }
    }

    /// Read the tensor back as owned f32s.
    pub fn to_f32_vec(&self) -> Result<Vec<f32>, String> {
        self.as_f32().map(|d| d.to_vec())
    }

    /// Read the tensor back as owned i32s.
    pub fn to_i32_vec(&self) -> Result<Vec<i32>, String> {
        self.as_i32().map(|d| d.to_vec())
    }

    /// First element as f32 (scalar reads: losses, flags).
    pub fn first_f32(&self) -> Result<f32, String> {
        match self {
            Tensor::F32 { data, .. } => data
                .first()
                .copied()
                .ok_or_else(|| "empty float32 tensor".to_string()),
            other => {
                Err(format!("expected float32 scalar, got {}", other.dtype()))
            }
        }
    }

    /// First element as i32 (scalar reads: prompt_len).
    pub fn first_i32(&self) -> Result<i32, String> {
        match self {
            Tensor::I32 { data, .. } => data
                .first()
                .copied()
                .ok_or_else(|| "empty int32 tensor".to_string()),
            other => {
                Err(format!("expected int32 scalar, got {}", other.dtype()))
            }
        }
    }

    /// First element as u32 (scalar reads: seeds).
    pub fn first_u32(&self) -> Result<u32, String> {
        match self {
            Tensor::U32 { data, .. } => data
                .first()
                .copied()
                .ok_or_else(|| "empty uint32 tensor".to_string()),
            other => {
                Err(format!("expected uint32 scalar, got {}", other.dtype()))
            }
        }
    }
}

fn check_numel(ctx: &str, shape: &[usize], got: usize) -> Result<(), String> {
    let numel: usize = shape.iter().product::<usize>().max(1);
    if got != numel {
        return Err(format!(
            "{ctx}: shape {shape:?} wants {numel} elements, got {got}"
        ));
    }
    Ok(())
}

/// f32 tensor with the given shape.
pub fn tensor_f32(shape: &[usize], data: &[f32]) -> Result<Tensor, String> {
    check_numel("tensor_f32", shape, data.len())?;
    Ok(Tensor::F32 { shape: shape.to_vec(), data: data.to_vec() })
}

/// i32 tensor with the given shape.
pub fn tensor_i32(shape: &[usize], data: &[i32]) -> Result<Tensor, String> {
    check_numel("tensor_i32", shape, data.len())?;
    Ok(Tensor::I32 { shape: shape.to_vec(), data: data.to_vec() })
}

pub fn scalar_f32(v: f32) -> Tensor {
    Tensor::F32 { shape: Vec::new(), data: vec![v] }
}

pub fn scalar_i32(v: i32) -> Tensor {
    Tensor::I32 { shape: Vec::new(), data: vec![v] }
}

pub fn scalar_u32(v: u32) -> Tensor {
    Tensor::U32 { shape: Vec::new(), data: vec![v] }
}

/// Zero-filled tensor matching a manifest tensor spec (f32 state groups).
pub fn zeros_for(spec: &TensorSpec) -> Result<Tensor, String> {
    tensor_f32(&spec.shape, &vec![0.0; spec.numel()])
}

// ---------------------------------------------------------------------
// typed ABI routing
// ---------------------------------------------------------------------

/// The four persistent state groups the trainer threads through
/// executables. Checkpoints key their group snapshots on
/// [`StateGroup::name`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum StateGroup {
    /// Model parameters (`params/...` and the frozen `base/...` weights).
    Params,
    /// Trainable adapter patches (`train/...`, LoRA).
    Train,
    /// Base-optimizer state (`opt/...`: Adam m/v, Adafactor vr/vc).
    Opt,
    /// Method-owned state (`acc/`, `mom/`, GaLore's `m/`, `proj/`, `v/`).
    Method,
}

impl StateGroup {
    pub const ALL: [StateGroup; 4] = [
        StateGroup::Params,
        StateGroup::Train,
        StateGroup::Opt,
        StateGroup::Method,
    ];

    pub fn name(self) -> &'static str {
        match self {
            StateGroup::Params => "params",
            StateGroup::Train => "train",
            StateGroup::Opt => "opt",
            StateGroup::Method => "method",
        }
    }

    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "params" => Ok(StateGroup::Params),
            "train" => Ok(StateGroup::Train),
            "opt" => Ok(StateGroup::Opt),
            "method" => Ok(StateGroup::Method),
            _ => Err(format!(
                "unknown state group {s:?} (want params|train|opt|method)"
            )),
        }
    }
}

/// Every scalar the manifest ABI passes into a step, typed. Adding a new
/// scalar to the ABI means adding a variant here — unknown names fail at
/// routing time with the executable that asked for them.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum ScalarKey {
    Lr,
    Step,
    /// Algorithm-1 cycle seed (also the GaLore refresh seed).
    Seed,
    /// Algorithm-2 current-subspace seed.
    SeedCur,
    /// Algorithm-2 next-subspace seed.
    SeedNext,
    /// Algorithm-2 resample flag (1.0 on κ-interval boundaries).
    Resample,
    /// AdaRank active rank BEFORE this step (adaptive rank schedule).
    RankCur,
    /// AdaRank active rank AFTER this step (shrinks only on resample).
    RankNext,
    /// Accumulation length τ.
    Tau,
    /// GaLore projection-refresh flag.
    Refresh,
    /// Greedy-decode prompt length.
    PromptLen,
}

impl ScalarKey {
    pub fn name(self) -> &'static str {
        match self {
            ScalarKey::Lr => "lr",
            ScalarKey::Step => "step",
            ScalarKey::Seed => "seed",
            ScalarKey::SeedCur => "seed_cur",
            ScalarKey::SeedNext => "seed_next",
            ScalarKey::Resample => "resample",
            ScalarKey::RankCur => "rank_cur",
            ScalarKey::RankNext => "rank_next",
            ScalarKey::Tau => "tau",
            ScalarKey::Refresh => "refresh",
            ScalarKey::PromptLen => "prompt_len",
        }
    }

    pub fn parse(s: &str) -> Option<ScalarKey> {
        match s {
            "lr" => Some(ScalarKey::Lr),
            "step" => Some(ScalarKey::Step),
            "seed" => Some(ScalarKey::Seed),
            "seed_cur" => Some(ScalarKey::SeedCur),
            "seed_next" => Some(ScalarKey::SeedNext),
            "resample" => Some(ScalarKey::Resample),
            "rank_cur" => Some(ScalarKey::RankCur),
            "rank_next" => Some(ScalarKey::RankNext),
            "tau" => Some(ScalarKey::Tau),
            "refresh" => Some(ScalarKey::Refresh),
            "prompt_len" => Some(ScalarKey::PromptLen),
            _ => None,
        }
    }
}

/// Result tensors a step yields besides state updates.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OutKind {
    Loss,
    /// Greedy-decoded token grid.
    Tokens,
    /// ViT class predictions.
    Preds,
}

/// Where one ABI tensor name routes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Route {
    State(StateGroup),
    Batch,
    Scalar(ScalarKey),
    Out(OutKind),
}

impl Route {
    /// Classify an ABI tensor name. Every name the catalogs emit resolves;
    /// anything else is a loud error naming the offender.
    pub fn of(name: &str) -> Result<Route, String> {
        match name {
            "loss" => return Ok(Route::Out(OutKind::Loss)),
            "tokens" => return Ok(Route::Out(OutKind::Tokens)),
            "preds" => return Ok(Route::Out(OutKind::Preds)),
            _ => {}
        }
        // method-owned state prefixes used by both catalogs (flora.py /
        // galore.py state_shapes): accumulator, momentum, GaLore moments +
        // stored projection, AltLoRA's left sketch. Unknown slash-names are
        // an ERROR, not Method — a typo'd group must fail at routing time,
        // never train as a silently zero-initialized tensor.
        const METHOD_PREFIXES: [&str; 6] =
            ["acc/", "mom/", "m/", "v/", "proj/", "ralt/"];
        if name.starts_with("params/") || name.starts_with("base/") {
            Ok(Route::State(StateGroup::Params))
        } else if name.starts_with("train/") {
            Ok(Route::State(StateGroup::Train))
        } else if name.starts_with("opt/") {
            Ok(Route::State(StateGroup::Opt))
        } else if name.starts_with("batch/") {
            Ok(Route::Batch)
        } else if METHOD_PREFIXES.iter().any(|p| name.starts_with(p)) {
            Ok(Route::State(StateGroup::Method))
        } else if name.contains('/') {
            Err(format!(
                "unroutable ABI tensor name {name:?}: unknown state-group \
                 prefix (known: params/, base/, train/, opt/, batch/, \
                 {METHOD_PREFIXES:?})"
            ))
        } else {
            ScalarKey::parse(name).map(Route::Scalar).ok_or_else(|| {
                format!(
                    "unroutable ABI tensor name {name:?}: not a state \
                     group, batch input, output, or known scalar key"
                )
            })
        }
    }
}

/// Builder for one executable invocation: typed scalars + the batch map.
/// State inputs are pulled from the [`StateStore`] by name at assembly
/// time, in the executable's declared input order.
///
/// # Example: assemble a fused step's inputs by name
///
/// ```
/// use std::path::PathBuf;
/// use flora::runtime::manifest::ExecutableInfo;
/// use flora::runtime::{StateGroup, StateStore, StepIo, TensorSpec};
///
/// let f32s = |name: &str, shape: &[usize]| TensorSpec {
///     name: name.into(),
///     shape: shape.to_vec(),
///     dtype: "float32".into(),
/// };
/// // an executable that consumes the params plus the (lr, step) pair
/// let info = ExecutableInfo {
///     name: "demo/plain_step_sgd".into(),
///     file: PathBuf::from("native"),
///     model: "demo".into(),
///     inputs: vec![f32s("params/w", &[2, 2]), f32s("lr", &[]), f32s("step", &[])],
///     outputs: vec![],
/// };
/// let mut state = StateStore::new(None);
/// state
///     .put_zeros(StateGroup::Params, vec![f32s("params/w", &[2, 2])])
///     .unwrap();
/// let inputs = StepIo::new().lr_step(0.1, 3).inputs_for(&info, &state).unwrap();
/// assert_eq!(inputs.len(), 3);
/// assert_eq!(inputs[1].first_f32().unwrap(), 0.1); // routed by NAME
/// assert_eq!(inputs[2].first_f32().unwrap(), 3.0);
/// ```
#[derive(Default)]
pub struct StepIo {
    scalars: BTreeMap<ScalarKey, Tensor>,
    batch: BTreeMap<String, Tensor>,
}

impl StepIo {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn scalar(mut self, key: ScalarKey, value: Tensor) -> Self {
        self.scalars.insert(key, value);
        self
    }

    /// The (lr, step) pair every update-bearing step consumes.
    pub fn lr_step(self, lr: f32, step: usize) -> Self {
        self.scalar(ScalarKey::Lr, scalar_f32(lr))
            .scalar(ScalarKey::Step, scalar_f32(step as f32))
    }

    pub fn seed(self, seed: u32) -> Self {
        self.scalar(ScalarKey::Seed, scalar_u32(seed))
    }

    pub fn batch(mut self, batch: BTreeMap<String, Tensor>) -> Self {
        self.batch = batch;
        self
    }

    /// True when the executable's ABI asks for this scalar.
    pub fn wants(info: &ExecutableInfo, key: ScalarKey) -> bool {
        info.inputs.iter().any(|t| t.name == key.name())
    }

    /// Assemble the input tensor list in manifest order, routing each
    /// declared input by name: state groups from `state`, batch tensors
    /// and scalars from this builder.
    pub fn inputs_for(
        &self,
        info: &ExecutableInfo,
        state: &StateStore,
    ) -> Result<Vec<Tensor>, String> {
        let ctx = &info.name;
        let mut out = Vec::with_capacity(info.inputs.len());
        for t in &info.inputs {
            let route = Route::of(&t.name).map_err(|e| format!("{ctx}: {e}"))?;
            let val = match route {
                Route::State(g) => state
                    .named(g, &t.name)
                    .map_err(|e| format!("{ctx}: {e}"))?
                    .clone(),
                Route::Batch => self
                    .batch
                    .get(&t.name)
                    .ok_or_else(|| format!("{ctx}: batch missing {}", t.name))?
                    .clone(),
                Route::Scalar(k) => self
                    .scalars
                    .get(&k)
                    .ok_or_else(|| {
                        format!("{ctx}: scalar {:?} not provided", k.name())
                    })?
                    .clone(),
                Route::Out(_) => {
                    return Err(format!(
                        "{ctx}: output-only name {} declared as input",
                        t.name
                    ))
                }
            };
            out.push(val);
        }
        Ok(out)
    }
}

/// An executed step's outputs, addressable by ABI name.
pub struct StepOutputs {
    exe: String,
    pairs: Vec<(TensorSpec, Tensor)>,
}

impl StepOutputs {
    pub fn of(info: &ExecutableInfo, outs: Vec<Tensor>) -> Result<Self, String> {
        if outs.len() != info.outputs.len() {
            return Err(format!(
                "{}: got {} outputs, manifest declares {}",
                info.name,
                outs.len(),
                info.outputs.len()
            ));
        }
        Ok(Self {
            exe: info.name.clone(),
            pairs: info.outputs.iter().cloned().zip(outs).collect(),
        })
    }

    /// The output named `name`, or an error listing what IS available.
    pub fn named(&self, name: &str) -> Result<&Tensor, String> {
        self.pairs
            .iter()
            .find(|(spec, _)| spec.name == name)
            .map(|(_, val)| val)
            .ok_or_else(|| {
                let have: Vec<&str> =
                    self.pairs.iter().map(|(s, _)| s.name.as_str()).collect();
                format!(
                    "{}: no output named {name:?} (outputs: {have:?})",
                    self.exe
                )
            })
    }

    /// The loss scalar, if this step produces one.
    pub fn loss(&self) -> Result<Option<f32>, String> {
        match self.pairs.iter().find(|(s, _)| s.name == "loss") {
            Some((_, val)) => val
                .first_f32()
                .map(Some)
                .map_err(|e| format!("{}: loss read: {e}", self.exe)),
            None => Ok(None),
        }
    }

    /// Route every state-group output back into the store by name.
    /// Consumes the outputs so state tensors are MOVED, not cloned —
    /// read `loss()`/`named()` before absorbing.
    pub fn absorb_into(self, state: &mut StateStore) -> Result<(), String> {
        for (spec, val) in self.pairs {
            match Route::of(&spec.name).map_err(|e| format!("{}: {e}", self.exe))? {
                Route::State(g) => state
                    .set_named(g, &spec.name, val)
                    .map_err(|e| format!("{}: {e}", self.exe))?,
                Route::Out(_) => {} // read via named()/loss()
                Route::Batch | Route::Scalar(_) => {
                    return Err(format!(
                        "{}: {} cannot appear in step outputs",
                        self.exe, spec.name
                    ))
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_roundtrip() {
        let t = tensor_f32(&[2, 3], &[1., 2., 3., 4., 5., 6.]).unwrap();
        assert_eq!(t.element_count(), 6);
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.to_f32_vec().unwrap(), vec![1., 2., 3., 4., 5., 6.]);
    }

    #[test]
    fn i32_roundtrip() {
        let t = tensor_i32(&[4], &[9, 8, 7, 6]).unwrap();
        assert_eq!(t.to_i32_vec().unwrap(), vec![9, 8, 7, 6]);
    }

    #[test]
    fn scalar_shapes() {
        let t = scalar_u32(42);
        assert_eq!(t.element_count(), 1);
        assert_eq!(t.first_u32().unwrap(), 42);
        let t = tensor_f32(&[], &[1.5]).unwrap();
        assert_eq!(t.element_count(), 1);
        assert_eq!(t.first_f32().unwrap(), 1.5);
        assert_eq!(scalar_i32(-3).first_i32().unwrap(), -3);
    }

    #[test]
    fn wrong_element_count_rejected() {
        assert!(tensor_f32(&[2, 2], &[1.0]).is_err());
        assert!(tensor_i32(&[3], &[1, 2]).is_err());
    }

    #[test]
    fn dtype_mismatch_rejected() {
        let t = scalar_f32(1.0);
        assert!(t.first_i32().is_err());
        assert!(t.first_u32().is_err());
        assert!(t.to_i32_vec().is_err());
        assert!(scalar_i32(1).first_f32().is_err());
    }

    #[test]
    fn zeros_for_spec() {
        let spec = TensorSpec {
            name: "acc/x".into(),
            shape: vec![3, 5],
            dtype: "float32".into(),
        };
        let t = zeros_for(&spec).unwrap();
        assert_eq!(t.element_count(), 15);
        assert_eq!(t.byte_size(), 60);
        assert!(t.to_f32_vec().unwrap().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn route_classifies_every_abi_name() {
        assert_eq!(
            Route::of("params/layer0/attn/wq").unwrap(),
            Route::State(StateGroup::Params)
        );
        assert_eq!(
            Route::of("base/embed/tok").unwrap(),
            Route::State(StateGroup::Params)
        );
        assert_eq!(
            Route::of("train/lora_A/l0").unwrap(),
            Route::State(StateGroup::Train)
        );
        assert_eq!(
            Route::of("opt/embed/tok/vr").unwrap(),
            Route::State(StateGroup::Opt)
        );
        for method_name in ["acc/w", "mom/w", "proj/w", "m/w", "v/w", "ralt/w"] {
            assert_eq!(
                Route::of(method_name).unwrap(),
                Route::State(StateGroup::Method),
                "{method_name}"
            );
        }
        assert_eq!(Route::of("batch/tokens").unwrap(), Route::Batch);
        assert_eq!(
            Route::of("seed_cur").unwrap(),
            Route::Scalar(ScalarKey::SeedCur)
        );
        assert_eq!(Route::of("lr").unwrap(), Route::Scalar(ScalarKey::Lr));
        assert_eq!(Route::of("loss").unwrap(), Route::Out(OutKind::Loss));
        assert_eq!(Route::of("tokens").unwrap(), Route::Out(OutKind::Tokens));
        assert_eq!(Route::of("preds").unwrap(), Route::Out(OutKind::Preds));
        let err = Route::of("warmup_frac").unwrap_err();
        assert!(err.contains("warmup_frac"), "{err}");
        // unknown slash-prefixes must fail loudly, never land in Method
        let err = Route::of("grads/w").unwrap_err();
        assert!(err.contains("grads/w"), "{err}");
        assert!(Route::of("opts/m/w").is_err(), "typo'd prefix accepted");
    }

    #[test]
    fn scalar_key_name_parse_roundtrip() {
        for key in [
            ScalarKey::Lr,
            ScalarKey::Step,
            ScalarKey::Seed,
            ScalarKey::SeedCur,
            ScalarKey::SeedNext,
            ScalarKey::Resample,
            ScalarKey::RankCur,
            ScalarKey::RankNext,
            ScalarKey::Tau,
            ScalarKey::Refresh,
            ScalarKey::PromptLen,
        ] {
            assert_eq!(ScalarKey::parse(key.name()), Some(key));
        }
        assert_eq!(ScalarKey::parse("nope"), None);
    }

    #[test]
    fn state_group_name_parse_roundtrip() {
        for g in StateGroup::ALL {
            assert_eq!(StateGroup::parse(g.name()).unwrap(), g);
        }
        assert!(StateGroup::parse("grads").is_err());
    }

    fn exe_info(inputs: Vec<TensorSpec>, outputs: Vec<TensorSpec>) -> ExecutableInfo {
        ExecutableInfo {
            name: "test/exe".into(),
            file: std::path::PathBuf::from("x"),
            model: "test".into(),
            inputs,
            outputs,
        }
    }

    fn fspec(name: &str, shape: &[usize]) -> TensorSpec {
        TensorSpec {
            name: name.into(),
            shape: shape.to_vec(),
            dtype: "float32".into(),
        }
    }

    #[test]
    fn step_io_assembles_in_manifest_order() {
        let mut state = StateStore::new(None);
        state
            .put_zeros(StateGroup::Params, vec![fspec("params/w", &[2, 2])])
            .unwrap();
        state
            .put_zeros(StateGroup::Opt, vec![fspec("opt/m/w", &[2, 2])])
            .unwrap();
        let info = exe_info(
            vec![
                fspec("params/w", &[2, 2]),
                fspec("opt/m/w", &[2, 2]),
                fspec("batch/tokens", &[1, 2]),
                fspec("lr", &[]),
                fspec("step", &[]),
            ],
            vec![],
        );
        let mut batch = BTreeMap::new();
        batch.insert("batch/tokens".to_string(), scalar_f32(7.0));
        let io = StepIo::new().lr_step(0.5, 3).batch(batch);
        let inputs = io.inputs_for(&info, &state).unwrap();
        assert_eq!(inputs.len(), 5);
        assert_eq!(inputs[3].first_f32().unwrap(), 0.5);
        assert_eq!(inputs[4].first_f32().unwrap(), 3.0);
    }

    #[test]
    fn step_io_missing_scalar_is_loud() {
        let state = StateStore::new(None);
        let info = exe_info(vec![fspec("lr", &[])], vec![]);
        let err = StepIo::new().inputs_for(&info, &state).unwrap_err();
        assert!(err.contains("lr"), "{err}");
        assert!(err.contains("test/exe"), "{err}");
    }

    #[test]
    fn step_io_wants_detects_scalars() {
        let info = exe_info(vec![fspec("seed_cur", &[])], vec![]);
        assert!(StepIo::wants(&info, ScalarKey::SeedCur));
        assert!(!StepIo::wants(&info, ScalarKey::Refresh));
    }

    #[test]
    fn step_outputs_route_by_name_not_position() {
        let info = exe_info(
            vec![],
            vec![
                fspec("loss", &[]),
                fspec("params/w", &[2, 2]),
                fspec("opt/m/w", &[2, 2]),
            ],
        );
        let outs = StepOutputs::of(
            &info,
            vec![
                scalar_f32(1.25),
                tensor_f32(&[2, 2], &[1.0, 2.0, 3.0, 4.0]).unwrap(),
                tensor_f32(&[2, 2], &[5.0, 6.0, 7.0, 8.0]).unwrap(),
            ],
        )
        .unwrap();
        assert_eq!(outs.loss().unwrap(), Some(1.25));
        assert_eq!(
            outs.named("opt/m/w").unwrap().to_f32_vec().unwrap(),
            vec![5.0, 6.0, 7.0, 8.0]
        );
        let err = outs.named("preds").unwrap_err();
        assert!(err.contains("preds") && err.contains("params/w"), "{err}");

        let mut state = StateStore::new(None);
        state
            .put_zeros(StateGroup::Params, vec![fspec("params/w", &[2, 2])])
            .unwrap();
        state
            .put_zeros(StateGroup::Opt, vec![fspec("opt/m/w", &[2, 2])])
            .unwrap();
        outs.absorb_into(&mut state).unwrap();
        let w = state.named(StateGroup::Params, "params/w").unwrap();
        assert_eq!(w.to_f32_vec().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn step_outputs_arity_mismatch_rejected() {
        let info = exe_info(vec![], vec![fspec("loss", &[])]);
        assert!(StepOutputs::of(&info, vec![]).is_err());
    }
}
