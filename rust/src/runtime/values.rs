//! Backend-neutral tensor values — the host side of the flat ABI.
//!
//! `Tensor` replaces `xla::Literal` everywhere above the backend boundary:
//! the coordinator moves named `Tensor` groups between executables and
//! never touches backend-specific buffers. Backends convert at their edge
//! (the PJRT backend to `Literal`s, the native backend to `tensor::Matrix`).

use super::manifest::TensorSpec;

/// A host tensor in one of the three dtypes the manifest ABI uses.
#[derive(Clone, Debug, PartialEq)]
pub enum Tensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
    U32 { shape: Vec<usize>, data: Vec<u32> },
}

impl Tensor {
    pub fn shape(&self) -> &[usize] {
        match self {
            Tensor::F32 { shape, .. }
            | Tensor::I32 { shape, .. }
            | Tensor::U32 { shape, .. } => shape,
        }
    }

    pub fn element_count(&self) -> usize {
        match self {
            Tensor::F32 { data, .. } => data.len(),
            Tensor::I32 { data, .. } => data.len(),
            Tensor::U32 { data, .. } => data.len(),
        }
    }

    pub fn dtype(&self) -> &'static str {
        match self {
            Tensor::F32 { .. } => "float32",
            Tensor::I32 { .. } => "int32",
            Tensor::U32 { .. } => "uint32",
        }
    }

    pub fn byte_size(&self) -> usize {
        self.element_count() * 4
    }

    /// Borrow the f32 payload.
    pub fn as_f32(&self) -> Result<&[f32], String> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            other => {
                Err(format!("expected float32 tensor, got {}", other.dtype()))
            }
        }
    }

    /// Borrow the i32 payload.
    pub fn as_i32(&self) -> Result<&[i32], String> {
        match self {
            Tensor::I32 { data, .. } => Ok(data),
            other => {
                Err(format!("expected int32 tensor, got {}", other.dtype()))
            }
        }
    }

    /// Read the tensor back as owned f32s.
    pub fn to_f32_vec(&self) -> Result<Vec<f32>, String> {
        self.as_f32().map(|d| d.to_vec())
    }

    /// Read the tensor back as owned i32s.
    pub fn to_i32_vec(&self) -> Result<Vec<i32>, String> {
        self.as_i32().map(|d| d.to_vec())
    }

    /// First element as f32 (scalar reads: losses, flags).
    pub fn first_f32(&self) -> Result<f32, String> {
        match self {
            Tensor::F32 { data, .. } => data
                .first()
                .copied()
                .ok_or_else(|| "empty float32 tensor".to_string()),
            other => {
                Err(format!("expected float32 scalar, got {}", other.dtype()))
            }
        }
    }

    /// First element as i32 (scalar reads: prompt_len).
    pub fn first_i32(&self) -> Result<i32, String> {
        match self {
            Tensor::I32 { data, .. } => data
                .first()
                .copied()
                .ok_or_else(|| "empty int32 tensor".to_string()),
            other => {
                Err(format!("expected int32 scalar, got {}", other.dtype()))
            }
        }
    }

    /// First element as u32 (scalar reads: seeds).
    pub fn first_u32(&self) -> Result<u32, String> {
        match self {
            Tensor::U32 { data, .. } => data
                .first()
                .copied()
                .ok_or_else(|| "empty uint32 tensor".to_string()),
            other => {
                Err(format!("expected uint32 scalar, got {}", other.dtype()))
            }
        }
    }
}

fn check_numel(ctx: &str, shape: &[usize], got: usize) -> Result<(), String> {
    let numel: usize = shape.iter().product::<usize>().max(1);
    if got != numel {
        return Err(format!(
            "{ctx}: shape {shape:?} wants {numel} elements, got {got}"
        ));
    }
    Ok(())
}

/// f32 tensor with the given shape.
pub fn tensor_f32(shape: &[usize], data: &[f32]) -> Result<Tensor, String> {
    check_numel("tensor_f32", shape, data.len())?;
    Ok(Tensor::F32 { shape: shape.to_vec(), data: data.to_vec() })
}

/// i32 tensor with the given shape.
pub fn tensor_i32(shape: &[usize], data: &[i32]) -> Result<Tensor, String> {
    check_numel("tensor_i32", shape, data.len())?;
    Ok(Tensor::I32 { shape: shape.to_vec(), data: data.to_vec() })
}

pub fn scalar_f32(v: f32) -> Tensor {
    Tensor::F32 { shape: Vec::new(), data: vec![v] }
}

pub fn scalar_i32(v: i32) -> Tensor {
    Tensor::I32 { shape: Vec::new(), data: vec![v] }
}

pub fn scalar_u32(v: u32) -> Tensor {
    Tensor::U32 { shape: Vec::new(), data: vec![v] }
}

/// Zero-filled tensor matching a manifest tensor spec (f32 state groups).
pub fn zeros_for(spec: &TensorSpec) -> Result<Tensor, String> {
    tensor_f32(&spec.shape, &vec![0.0; spec.numel()])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_roundtrip() {
        let t = tensor_f32(&[2, 3], &[1., 2., 3., 4., 5., 6.]).unwrap();
        assert_eq!(t.element_count(), 6);
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.to_f32_vec().unwrap(), vec![1., 2., 3., 4., 5., 6.]);
    }

    #[test]
    fn i32_roundtrip() {
        let t = tensor_i32(&[4], &[9, 8, 7, 6]).unwrap();
        assert_eq!(t.to_i32_vec().unwrap(), vec![9, 8, 7, 6]);
    }

    #[test]
    fn scalar_shapes() {
        let t = scalar_u32(42);
        assert_eq!(t.element_count(), 1);
        assert_eq!(t.first_u32().unwrap(), 42);
        let t = tensor_f32(&[], &[1.5]).unwrap();
        assert_eq!(t.element_count(), 1);
        assert_eq!(t.first_f32().unwrap(), 1.5);
        assert_eq!(scalar_i32(-3).first_i32().unwrap(), -3);
    }

    #[test]
    fn wrong_element_count_rejected() {
        assert!(tensor_f32(&[2, 2], &[1.0]).is_err());
        assert!(tensor_i32(&[3], &[1, 2]).is_err());
    }

    #[test]
    fn dtype_mismatch_rejected() {
        let t = scalar_f32(1.0);
        assert!(t.first_i32().is_err());
        assert!(t.first_u32().is_err());
        assert!(t.to_i32_vec().is_err());
        assert!(scalar_i32(1).first_f32().is_err());
    }

    #[test]
    fn zeros_for_spec() {
        let spec = TensorSpec {
            name: "acc/x".into(),
            shape: vec![3, 5],
            dtype: "float32".into(),
        };
        let t = zeros_for(&spec).unwrap();
        assert_eq!(t.element_count(), 15);
        assert_eq!(t.byte_size(), 60);
        assert!(t.to_f32_vec().unwrap().iter().all(|&x| x == 0.0));
    }
}
