//! Literal construction/extraction helpers — the host side of the flat ABI.

use xla::Literal;

use super::manifest::TensorSpec;

/// f32 tensor literal with the given shape.
pub fn literal_f32(shape: &[usize], data: &[f32]) -> Result<Literal, String> {
    let numel: usize = shape.iter().product::<usize>().max(1);
    if data.len() != numel {
        return Err(format!(
            "literal_f32: shape {shape:?} wants {numel} elements, got {}",
            data.len()
        ));
    }
    if shape.is_empty() {
        return Ok(Literal::scalar(data[0]));
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Literal::vec1(data)
        .reshape(&dims)
        .map_err(|e| format!("reshape: {e:?}"))
}

/// i32 tensor literal with the given shape.
pub fn literal_i32(shape: &[usize], data: &[i32]) -> Result<Literal, String> {
    let numel: usize = shape.iter().product::<usize>().max(1);
    if data.len() != numel {
        return Err(format!(
            "literal_i32: shape {shape:?} wants {numel} elements, got {}",
            data.len()
        ));
    }
    if shape.is_empty() {
        return Ok(Literal::scalar(data[0]));
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Literal::vec1(data)
        .reshape(&dims)
        .map_err(|e| format!("reshape: {e:?}"))
}

pub fn scalar_f32(v: f32) -> Literal {
    Literal::scalar(v)
}

pub fn scalar_i32(v: i32) -> Literal {
    Literal::scalar(v)
}

pub fn scalar_u32(v: u32) -> Literal {
    Literal::scalar(v)
}

/// Read a literal back as f32s.
pub fn literal_to_f32(l: &Literal) -> Result<Vec<f32>, String> {
    l.to_vec::<f32>().map_err(|e| format!("to_vec f32: {e:?}"))
}

/// Read a literal back as i32s.
pub fn literal_to_i32(l: &Literal) -> Result<Vec<i32>, String> {
    l.to_vec::<i32>().map_err(|e| format!("to_vec i32: {e:?}"))
}

/// Zero-filled literal matching a manifest tensor spec (f32 state groups).
pub fn zeros_for(spec: &TensorSpec) -> Result<Literal, String> {
    literal_f32(&spec.shape, &vec![0.0; spec.numel()])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_roundtrip() {
        let l = literal_f32(&[2, 3], &[1., 2., 3., 4., 5., 6.]).unwrap();
        assert_eq!(l.element_count(), 6);
        assert_eq!(literal_to_f32(&l).unwrap(), vec![1., 2., 3., 4., 5., 6.]);
    }

    #[test]
    fn i32_roundtrip() {
        let l = literal_i32(&[4], &[9, 8, 7, 6]).unwrap();
        assert_eq!(literal_to_i32(&l).unwrap(), vec![9, 8, 7, 6]);
    }

    #[test]
    fn scalar_shapes() {
        let l = scalar_u32(42);
        assert_eq!(l.element_count(), 1);
        let l = literal_f32(&[], &[1.5]).unwrap();
        assert_eq!(l.element_count(), 1);
    }

    #[test]
    fn wrong_element_count_rejected() {
        assert!(literal_f32(&[2, 2], &[1.0]).is_err());
        assert!(literal_i32(&[3], &[1, 2]).is_err());
    }

    #[test]
    fn zeros_for_spec() {
        let spec = TensorSpec {
            name: "acc/x".into(),
            shape: vec![3, 5],
            dtype: "float32".into(),
        };
        let l = zeros_for(&spec).unwrap();
        assert_eq!(l.element_count(), 15);
        assert!(literal_to_f32(&l).unwrap().iter().all(|&x| x == 0.0));
    }
}
