//! artifacts/manifest.json — the ABI contract emitted by python/compile/aot.py.
//!
//! For every executable it records the ordered input and output tensors
//! (name, shape, dtype). The coordinator assembles input literal lists in
//! exactly this order and maps outputs back into named state groups.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::json::{self, Json};

#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }

    pub fn byte_size(&self) -> usize {
        self.numel() * 4 // f32/i32/u32 only in this ABI
    }

    /// group prefix, e.g. "params" for "params/layer0/attn/wq"
    pub fn group(&self) -> &str {
        self.name.split('/').next().unwrap_or("")
    }
}

#[derive(Clone, Debug)]
pub struct ExecutableInfo {
    pub name: String,
    pub file: PathBuf,
    pub model: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

impl ExecutableInfo {
    /// Input specs whose name starts with `prefix/`.
    pub fn inputs_in_group(&self, prefix: &str) -> Vec<&TensorSpec> {
        self.inputs
            .iter()
            .filter(|t| t.group() == prefix)
            .collect()
    }

    pub fn outputs_in_group(&self, prefix: &str) -> Vec<&TensorSpec> {
        self.outputs
            .iter()
            .filter(|t| t.group() == prefix)
            .collect()
    }
}

#[derive(Clone, Debug)]
pub struct ModelInfo {
    pub name: String,
    pub kind: String,
    pub fields: BTreeMap<String, f64>,
}

impl ModelInfo {
    pub fn get(&self, key: &str) -> Option<usize> {
        self.fields.get(key).map(|v| *v as usize)
    }
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub executables: BTreeMap<String, ExecutableInfo>,
    pub models: BTreeMap<String, ModelInfo>,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Self, String> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            format!(
                "cannot read {} — run `make artifacts` first ({e})",
                path.display()
            )
        })?;
        Self::parse(&text, dir)
    }

    fn parse(text: &str, dir: PathBuf) -> Result<Self, String> {
        let root = json::parse(text).map_err(|e| e.to_string())?;
        let version = root
            .get("version")
            .and_then(Json::as_usize)
            .ok_or("manifest missing version")?;
        if version != 1 {
            return Err(format!("unsupported manifest version {version}"));
        }

        let mut models = BTreeMap::new();
        for (name, m) in root
            .get("models")
            .and_then(Json::as_obj)
            .ok_or("manifest missing models")?
        {
            let kind = m
                .get("kind")
                .and_then(Json::as_str)
                .unwrap_or("lm")
                .to_string();
            let mut fields = BTreeMap::new();
            if let Some(obj) = m.as_obj() {
                for (k, v) in obj {
                    if let Some(f) = v.as_f64() {
                        fields.insert(k.clone(), f);
                    }
                }
            }
            models.insert(
                name.clone(),
                ModelInfo { name: name.clone(), kind, fields },
            );
        }

        let mut executables = BTreeMap::new();
        for (name, e) in root
            .get("executables")
            .and_then(Json::as_obj)
            .ok_or("manifest missing executables")?
        {
            let file = e
                .get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| format!("{name}: missing file"))?;
            let model = e
                .get("model")
                .and_then(Json::as_str)
                .unwrap_or("")
                .to_string();
            let inputs = parse_specs(e.get("inputs"), name)?;
            let outputs = parse_specs(e.get("outputs"), name)?;
            executables.insert(
                name.clone(),
                ExecutableInfo {
                    name: name.clone(),
                    file: dir.join(file),
                    model,
                    inputs,
                    outputs,
                },
            );
        }
        Ok(Self { dir, executables, models })
    }

    pub fn executable(&self, name: &str) -> Result<&ExecutableInfo, String> {
        self.executables.get(name).ok_or_else(|| {
            format!(
                "executable {name:?} not in manifest (have: {} entries; \
                 rebuild artifacts?)",
                self.executables.len()
            )
        })
    }

    pub fn model(&self, name: &str) -> Result<&ModelInfo, String> {
        self.models
            .get(name)
            .ok_or_else(|| format!("model {name:?} not in manifest"))
    }
}

fn parse_specs(j: Option<&Json>, ctx: &str) -> Result<Vec<TensorSpec>, String> {
    let arr = j
        .and_then(Json::as_arr)
        .ok_or_else(|| format!("{ctx}: missing tensor specs"))?;
    arr.iter()
        .map(|t| {
            let name = t
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| format!("{ctx}: spec missing name"))?
                .to_string();
            let shape = t
                .get("shape")
                .and_then(Json::as_arr)
                .ok_or_else(|| format!("{ctx}/{name}: missing shape"))?
                .iter()
                .map(|d| d.as_usize().ok_or_else(|| format!("{ctx}: bad dim")))
                .collect::<Result<Vec<_>, _>>()?;
            let dtype = t
                .get("dtype")
                .and_then(Json::as_str)
                .unwrap_or("float32")
                .to_string();
            Ok(TensorSpec { name, shape, dtype })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    const DOC: &str = r#"{
      "version": 1,
      "models": {
        "lm-tiny": {"kind": "lm", "vocab": 64, "d_model": 32, "seq_len": 32,
                    "n_layers": 2, "n_heads": 2, "d_ff": 64, "name": "lm-tiny"}
      },
      "executables": {
        "lm-tiny/init": {
          "file": "lm-tiny__init.hlo.txt",
          "model": "lm-tiny",
          "inputs": [{"name": "seed", "shape": [], "dtype": "uint32"}],
          "outputs": [
            {"name": "params/embed/tok", "shape": [64, 32], "dtype": "float32"},
            {"name": "params/final_ln/scale", "shape": [32], "dtype": "float32"}
          ]
        }
      }
    }"#;

    #[test]
    fn parses_manifest_document() {
        let m = Manifest::parse(DOC, PathBuf::from("/tmp/a")).unwrap();
        let e = m.executable("lm-tiny/init").unwrap();
        assert_eq!(e.inputs.len(), 1);
        assert_eq!(e.outputs[0].shape, vec![64, 32]);
        assert_eq!(e.outputs[0].numel(), 2048);
        assert_eq!(e.outputs[0].group(), "params");
        assert_eq!(e.file, PathBuf::from("/tmp/a/lm-tiny__init.hlo.txt"));
        assert_eq!(m.model("lm-tiny").unwrap().get("vocab"), Some(64));
    }

    #[test]
    fn scalar_spec_numel_is_one() {
        let t = TensorSpec { name: "seed".into(), shape: vec![], dtype: "uint32".into() };
        assert_eq!(t.numel(), 1);
        assert_eq!(t.byte_size(), 4);
    }

    #[test]
    fn missing_executable_is_helpful() {
        let m = Manifest::parse(DOC, PathBuf::from("/tmp")).unwrap();
        let err = m.executable("nope").unwrap_err();
        assert!(err.contains("not in manifest"));
    }

    #[test]
    fn group_filters() {
        let m = Manifest::parse(DOC, PathBuf::from("/tmp")).unwrap();
        let e = m.executable("lm-tiny/init").unwrap();
        assert_eq!(e.outputs_in_group("params").len(), 2);
        assert_eq!(e.outputs_in_group("opt").len(), 0);
    }

    #[test]
    fn rejects_wrong_version() {
        let doc = DOC.replace("\"version\": 1", "\"version\": 9");
        assert!(Manifest::parse(&doc, PathBuf::from("/tmp")).is_err());
    }
}
