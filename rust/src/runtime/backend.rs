//! The execution-backend boundary.
//!
//! Everything above this trait pair (state store, trainer, benches, CLI)
//! works with backend-neutral `Tensor`s and manifest metadata; everything
//! below owns compilation, device buffers and the actual math. Two
//! implementations exist:
//!
//!   * `runtime::native::NativeBackend` — pure rust, default, no external
//!     libraries (the generated catalog implements the fused steps on
//!     `tensor::Matrix` + `rp`);
//!   * `runtime::pjrt::PjrtBackend` — the original PJRT/XLA path over AOT
//!     HLO-text artifacts, behind the `xla` cargo feature.

use std::rc::Rc;

use super::manifest::ExecutableInfo;
use super::values::Tensor;

/// A compiled/prepared executable: a pure function from the manifest's
/// ordered inputs to its ordered outputs. State is threaded through the
/// ABI, never held behind this trait.
pub trait BackendExec {
    fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>, String>;
}

/// An execution engine that can materialize manifest executables.
pub trait Backend {
    fn name(&self) -> &'static str;

    /// Compile (or fetch from an internal cache) the executable described
    /// by a manifest entry.
    fn compile(
        &mut self,
        info: &ExecutableInfo,
    ) -> Result<Rc<dyn BackendExec>, String>;
}
