//! Typed state groups: the training state the coordinator threads through
//! executables. Each [`StateGroup`] (params, train, opt, method) is an
//! ordered list of backend-neutral tensors whose specs carry the ABI
//! names; lookups and replacements are by NAME, so executable output
//! order can never silently mis-route a tensor. The ledger tracks byte
//! footprints so integration tests can reconcile the live numbers with
//! the analytic accountant.

use std::collections::BTreeMap;

use super::manifest::TensorSpec;
use super::values::{zeros_for, StateGroup, Tensor};
use crate::memory::BufferLedger;

/// One checkpointable group snapshot: group name + (spec, host f32 data)
/// pairs, in ABI order.
pub type GroupHostSnapshot = (String, Vec<(TensorSpec, Vec<f32>)>);

/// One group of state tensors.
pub struct Group {
    pub specs: Vec<TensorSpec>,
    pub values: Vec<Tensor>,
}

impl Group {
    pub fn byte_size(&self) -> u64 {
        self.specs.iter().map(|s| s.byte_size() as u64).sum()
    }
}

/// All state for one training run.
#[derive(Default)]
pub struct StateStore {
    groups: BTreeMap<StateGroup, Group>,
    ledger: Option<BufferLedger>,
}

impl StateStore {
    pub fn new(ledger: Option<BufferLedger>) -> Self {
        Self { groups: BTreeMap::new(), ledger }
    }

    /// Install a group from executed outputs (consumes the tensors).
    pub fn put(&mut self, group: StateGroup, specs: Vec<TensorSpec>, values: Vec<Tensor>) {
        assert_eq!(
            specs.len(),
            values.len(),
            "group {}: spec/value mismatch",
            group.name()
        );
        let g = Group { specs, values };
        if let Some(l) = &self.ledger {
            l.alloc(g.byte_size());
            if let Some(old) = self.groups.get(&group) {
                l.free(old.byte_size());
            }
        }
        self.groups.insert(group, g);
    }

    /// Allocate a zero-filled group matching manifest specs (accumulators,
    /// momenta, optimizer state start at zero in this ABI).
    pub fn put_zeros(
        &mut self,
        group: StateGroup,
        specs: Vec<TensorSpec>,
    ) -> Result<(), String> {
        let values = specs
            .iter()
            .map(zeros_for)
            .collect::<Result<Vec<_>, _>>()?;
        self.put(group, specs, values);
        Ok(())
    }

    pub fn get(&self, group: StateGroup) -> Result<&Group, String> {
        self.groups.get(&group).ok_or_else(|| {
            format!("state group {:?} not initialized", group.name())
        })
    }

    pub fn contains(&self, group: StateGroup) -> bool {
        self.groups.contains_key(&group)
    }

    /// The tensor named `name` within a group.
    pub fn named(&self, group: StateGroup, name: &str) -> Result<&Tensor, String> {
        let g = self.get(group)?;
        g.specs
            .iter()
            .position(|s| s.name == name)
            .and_then(|i| g.values.get(i))
            .ok_or_else(|| {
                let have: Vec<&str> =
                    g.specs.iter().map(|s| s.name.as_str()).collect();
                format!(
                    "group {} has no tensor named {name:?} (have: {have:?})",
                    group.name()
                )
            })
    }

    /// Replace one named tensor (post-step state routing). The shape is
    /// fixed by the group's spec; only the value moves.
    pub fn set_named(
        &mut self,
        group: StateGroup,
        name: &str,
        value: Tensor,
    ) -> Result<(), String> {
        let g = self.groups.get_mut(&group).ok_or_else(|| {
            format!("state group {:?} not initialized", group.name())
        })?;
        let idx = g.specs.iter().position(|s| s.name == name).ok_or_else(|| {
            let have: Vec<&str> =
                g.specs.iter().map(|s| s.name.as_str()).collect();
            format!(
                "group {} has no tensor named {name:?} (have: {have:?})",
                group.name()
            )
        })?;
        g.values[idx] = value;
        Ok(())
    }

    /// Zero a group in place (end of an accumulation cycle, Algorithm 1).
    pub fn zero(&mut self, group: StateGroup) -> Result<(), String> {
        let g = self.groups.get_mut(&group).ok_or_else(|| {
            format!("state group {:?} not initialized", group.name())
        })?;
        g.values = g
            .specs
            .iter()
            .map(zeros_for)
            .collect::<Result<Vec<_>, _>>()?;
        Ok(())
    }

    /// Assemble an input tensor list by cloning groups in order.
    pub fn collect(&self, groups: &[StateGroup]) -> Result<Vec<Tensor>, String> {
        let mut out = Vec::new();
        for group in groups {
            let g = self.get(*group)?;
            out.extend(g.values.iter().cloned());
        }
        Ok(out)
    }

    pub fn total_bytes(&self) -> u64 {
        self.groups.values().map(|g| g.byte_size()).sum()
    }

    pub fn group_bytes(&self, group: StateGroup) -> u64 {
        self.groups.get(&group).map(|g| g.byte_size()).unwrap_or(0)
    }

    /// Host snapshot of every group (f32 state only — the full ABI), for
    /// checkpointing. Group names are [`StateGroup::name`] strings so the
    /// checkpoint format stays self-describing.
    pub fn snapshot(&self) -> Result<Vec<GroupHostSnapshot>, String> {
        self.groups
            .iter()
            .map(|(group, g)| {
                let tensors = g
                    .specs
                    .iter()
                    .zip(g.values.iter())
                    .map(|(spec, val)| {
                        let data = val
                            .to_f32_vec()
                            .map_err(|e| format!("{}: {e}", spec.name))?;
                        Ok((spec.clone(), data))
                    })
                    .collect::<Result<Vec<_>, String>>()?;
                Ok((group.name().to_string(), tensors))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(name: &str, shape: &[usize]) -> TensorSpec {
        TensorSpec { name: name.into(), shape: shape.to_vec(), dtype: "float32".into() }
    }

    #[test]
    fn zeros_group_and_bytes() {
        let mut s = StateStore::new(Some(BufferLedger::new()));
        s.put_zeros(
            StateGroup::Method,
            vec![spec("acc/a", &[4, 8]), spec("acc/b", &[16])],
        )
        .unwrap();
        assert_eq!(s.group_bytes(StateGroup::Method), (32 + 16) * 4);
        assert_eq!(s.total_bytes(), 192);
        assert!(s.contains(StateGroup::Method));
        assert!(!s.contains(StateGroup::Opt));
    }

    #[test]
    fn collect_orders_groups() {
        let mut s = StateStore::new(None);
        s.put_zeros(StateGroup::Params, vec![spec("params/x", &[2])])
            .unwrap();
        s.put_zeros(StateGroup::Opt, vec![spec("opt/y", &[3])]).unwrap();
        let vals = s.collect(&[StateGroup::Opt, StateGroup::Params]).unwrap();
        assert_eq!(vals.len(), 2);
        assert_eq!(vals[0].element_count(), 3);
        assert_eq!(vals[1].element_count(), 2);
    }

    #[test]
    fn missing_group_errors() {
        let s = StateStore::new(None);
        assert!(s.get(StateGroup::Method).is_err());
        assert!(s.collect(&[StateGroup::Method]).is_err());
        assert!(s.named(StateGroup::Method, "acc/w").is_err());
    }

    #[test]
    fn named_lookup_and_replace() {
        let mut s = StateStore::new(None);
        s.put_zeros(
            StateGroup::Opt,
            vec![spec("opt/m/w", &[2]), spec("opt/v/w", &[2])],
        )
        .unwrap();
        let v = crate::runtime::tensor_f32(&[2], &[1.5, 2.5]).unwrap();
        s.set_named(StateGroup::Opt, "opt/v/w", v).unwrap();
        assert_eq!(
            s.named(StateGroup::Opt, "opt/v/w").unwrap().to_f32_vec().unwrap(),
            vec![1.5, 2.5]
        );
        // the sibling is untouched
        assert_eq!(
            s.named(StateGroup::Opt, "opt/m/w").unwrap().to_f32_vec().unwrap(),
            vec![0.0, 0.0]
        );
        // unknown names are loud and name what exists
        let err = s.set_named(StateGroup::Opt, "opt/zz", crate::runtime::scalar_f32(0.0));
        assert!(err.unwrap_err().contains("opt/m/w"));
    }

    #[test]
    fn zero_resets_values() {
        let mut s = StateStore::new(None);
        s.put_zeros(StateGroup::Method, vec![spec("acc/w", &[2])]).unwrap();
        let v = crate::runtime::tensor_f32(&[2], &[3.0, 4.0]).unwrap();
        s.set_named(StateGroup::Method, "acc/w", v).unwrap();
        s.zero(StateGroup::Method).unwrap();
        assert_eq!(
            s.named(StateGroup::Method, "acc/w").unwrap().to_f32_vec().unwrap(),
            vec![0.0, 0.0]
        );
    }

    #[test]
    fn ledger_sees_allocations() {
        let ledger = BufferLedger::new();
        let mut s = StateStore::new(Some(ledger.clone()));
        s.put_zeros(StateGroup::Params, vec![spec("params/w", &[100])])
            .unwrap();
        assert_eq!(ledger.current(), 400);
        // re-putting the same group frees the old bytes
        s.put_zeros(StateGroup::Params, vec![spec("params/w", &[100])])
            .unwrap();
        assert_eq!(ledger.current(), 400);
    }

    #[test]
    fn snapshot_uses_group_names() {
        let mut s = StateStore::new(None);
        s.put_zeros(StateGroup::Opt, vec![spec("opt/m/w", &[2])]).unwrap();
        let snap = s.snapshot().unwrap();
        assert_eq!(snap.len(), 1);
        assert_eq!(snap[0].0, "opt");
        assert_eq!(snap[0].1[0].0.name, "opt/m/w");
    }
}
