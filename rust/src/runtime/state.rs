//! Named tensor groups: the training state the coordinator threads through
//! executables. Each group ("params", "opt", "acc", "mom", ...) is an
//! ordered list of backend-neutral tensors matching the manifest's
//! sorted-name order; the ledger tracks their byte footprint so integration
//! tests can reconcile the live numbers with the analytic accountant.

use std::collections::BTreeMap;

use super::manifest::TensorSpec;
use super::values::{zeros_for, Tensor};
use crate::memory::BufferLedger;

/// One named group of state tensors.
pub struct Group {
    pub specs: Vec<TensorSpec>,
    pub values: Vec<Tensor>,
}

impl Group {
    pub fn byte_size(&self) -> u64 {
        self.specs.iter().map(|s| s.byte_size() as u64).sum()
    }
}

/// All state for one training run.
#[derive(Default)]
pub struct StateStore {
    groups: BTreeMap<String, Group>,
    ledger: Option<BufferLedger>,
}

impl StateStore {
    pub fn new(ledger: Option<BufferLedger>) -> Self {
        Self { groups: BTreeMap::new(), ledger }
    }

    /// Install a group from executed outputs (consumes the tensors).
    pub fn put(&mut self, name: &str, specs: Vec<TensorSpec>, values: Vec<Tensor>) {
        assert_eq!(specs.len(), values.len(), "group {name}: spec/value mismatch");
        let g = Group { specs, values };
        if let Some(l) = &self.ledger {
            l.alloc(g.byte_size());
            if let Some(old) = self.groups.get(name) {
                l.free(old.byte_size());
            }
        }
        self.groups.insert(name.to_string(), g);
    }

    /// Allocate a zero-filled group matching manifest specs (accumulators,
    /// momenta, optimizer state start at zero in this ABI).
    pub fn put_zeros(&mut self, name: &str, specs: Vec<TensorSpec>) -> Result<(), String> {
        let values = specs
            .iter()
            .map(zeros_for)
            .collect::<Result<Vec<_>, _>>()?;
        self.put(name, specs, values);
        Ok(())
    }

    pub fn get(&self, name: &str) -> Result<&Group, String> {
        self.groups
            .get(name)
            .ok_or_else(|| format!("state group {name:?} not initialized"))
    }

    pub fn contains(&self, name: &str) -> bool {
        self.groups.contains_key(name)
    }

    /// Replace a group's values (shapes unchanged — e.g. post-step params).
    pub fn replace_values(&mut self, name: &str, values: Vec<Tensor>) -> Result<(), String> {
        let g = self
            .groups
            .get_mut(name)
            .ok_or_else(|| format!("state group {name:?} not initialized"))?;
        if values.len() != g.values.len() {
            return Err(format!(
                "group {name}: replacing {} values with {}",
                g.values.len(),
                values.len()
            ));
        }
        g.values = values;
        Ok(())
    }

    /// Zero a group in place (end of an accumulation cycle, Algorithm 1).
    pub fn zero(&mut self, name: &str) -> Result<(), String> {
        let g = self
            .groups
            .get_mut(name)
            .ok_or_else(|| format!("state group {name:?} not initialized"))?;
        g.values = g
            .specs
            .iter()
            .map(zeros_for)
            .collect::<Result<Vec<_>, _>>()?;
        Ok(())
    }

    /// Assemble an input tensor list by cloning groups in order.
    pub fn collect(&self, group_names: &[&str]) -> Result<Vec<Tensor>, String> {
        let mut out = Vec::new();
        for name in group_names {
            let g = self.get(name)?;
            out.extend(g.values.iter().cloned());
        }
        Ok(out)
    }

    pub fn total_bytes(&self) -> u64 {
        self.groups.values().map(|g| g.byte_size()).sum()
    }

    pub fn group_bytes(&self, name: &str) -> u64 {
        self.groups.get(name).map(|g| g.byte_size()).unwrap_or(0)
    }

    /// Host snapshot of every group (f32 state only — the full ABI), for
    /// checkpointing.
    pub fn snapshot(&self) -> Result<Vec<(String, Vec<(TensorSpec, Vec<f32>)>)>, String> {
        self.groups
            .iter()
            .map(|(name, g)| {
                let tensors = g
                    .specs
                    .iter()
                    .zip(g.values.iter())
                    .map(|(spec, val)| {
                        let data = val
                            .to_f32_vec()
                            .map_err(|e| format!("{}: {e}", spec.name))?;
                        Ok((spec.clone(), data))
                    })
                    .collect::<Result<Vec<_>, String>>()?;
                Ok((name.clone(), tensors))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(name: &str, shape: &[usize]) -> TensorSpec {
        TensorSpec { name: name.into(), shape: shape.to_vec(), dtype: "float32".into() }
    }

    #[test]
    fn zeros_group_and_bytes() {
        let mut s = StateStore::new(Some(BufferLedger::new()));
        s.put_zeros("acc", vec![spec("acc/a", &[4, 8]), spec("acc/b", &[16])])
            .unwrap();
        assert_eq!(s.group_bytes("acc"), (32 + 16) * 4);
        assert_eq!(s.total_bytes(), 192);
        assert!(s.contains("acc"));
    }

    #[test]
    fn collect_orders_groups() {
        let mut s = StateStore::new(None);
        s.put_zeros("a", vec![spec("a/x", &[2])]).unwrap();
        s.put_zeros("b", vec![spec("b/y", &[3])]).unwrap();
        let vals = s.collect(&["b", "a"]).unwrap();
        assert_eq!(vals.len(), 2);
        assert_eq!(vals[0].element_count(), 3);
        assert_eq!(vals[1].element_count(), 2);
    }

    #[test]
    fn missing_group_errors() {
        let s = StateStore::new(None);
        assert!(s.get("nope").is_err());
        assert!(s.collect(&["nope"]).is_err());
    }

    #[test]
    fn replace_value_count_checked() {
        let mut s = StateStore::new(None);
        s.put_zeros("g", vec![spec("g/x", &[2]), spec("g/y", &[2])]).unwrap();
        assert!(s.replace_values("g", vec![]).is_err());
    }

    #[test]
    fn ledger_sees_allocations() {
        let ledger = BufferLedger::new();
        let mut s = StateStore::new(Some(ledger.clone()));
        s.put_zeros("p", vec![spec("p/w", &[100])]).unwrap();
        assert_eq!(ledger.current(), 400);
        // re-putting the same group frees the old bytes
        s.put_zeros("p", vec![spec("p/w", &[100])]).unwrap();
        assert_eq!(ledger.current(), 400);
    }
}
