//! PJRT client wrapper: HLO-text loading, compile caching, execution with
//! ABI validation, and ledger-tracked output sizes.

use std::collections::HashMap;
use std::rc::Rc;
use std::time::Instant;

use xla::{Literal, PjRtClient, PjRtLoadedExecutable};

use super::manifest::{ExecutableInfo, Manifest};
use crate::memory::BufferLedger;
use crate::{debug, info};

/// A compiled executable plus its manifest metadata.
pub struct Executable {
    pub info: ExecutableInfo,
    exe: PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with ABI validation. Inputs must match `info.inputs` in
    /// count; outputs are the decomposed result tuple in `info.outputs`
    /// order (aot.py lowers with return_tuple=True).
    pub fn run(&self, inputs: &[Literal]) -> Result<Vec<Literal>, String> {
        if inputs.len() != self.info.inputs.len() {
            return Err(format!(
                "{}: got {} inputs, manifest wants {} (first expected: {:?})",
                self.info.name,
                inputs.len(),
                self.info.inputs.len(),
                self.info.inputs.first().map(|t| &t.name),
            ));
        }
        let bufs = self
            .exe
            .execute::<Literal>(inputs)
            .map_err(|e| format!("{}: execute: {e:?}", self.info.name))?;
        let result = bufs[0][0]
            .to_literal_sync()
            .map_err(|e| format!("{}: to_literal: {e:?}", self.info.name))?;
        let outputs = result
            .to_tuple()
            .map_err(|e| format!("{}: untuple: {e:?}", self.info.name))?;
        if outputs.len() != self.info.outputs.len() {
            return Err(format!(
                "{}: got {} outputs, manifest wants {}",
                self.info.name,
                outputs.len(),
                self.info.outputs.len()
            ));
        }
        Ok(outputs)
    }
}

/// The runtime: one PJRT CPU client + a compile cache over the manifest.
pub struct Runtime {
    pub manifest: Manifest,
    pub ledger: BufferLedger,
    client: PjRtClient,
    cache: HashMap<String, Rc<Executable>>,
}

impl Runtime {
    /// Create a CPU runtime over an artifacts directory.
    pub fn new(artifacts_dir: &str) -> Result<Self, String> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client =
            PjRtClient::cpu().map_err(|e| format!("PjRtClient::cpu: {e:?}"))?;
        info!(
            "runtime up: platform={} artifacts={} ({} executables)",
            client.platform_name(),
            artifacts_dir,
            manifest.executables.len()
        );
        Ok(Self { manifest, client, cache: HashMap::new(), ledger: BufferLedger::new() })
    }

    /// Load + compile (cached) an executable by manifest name.
    pub fn load(&mut self, name: &str) -> Result<Rc<Executable>, String> {
        if let Some(e) = self.cache.get(name) {
            return Ok(e.clone());
        }
        let info = self.manifest.executable(name)?.clone();
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            info.file
                .to_str()
                .ok_or_else(|| format!("{name}: non-utf8 path"))?,
        )
        .map_err(|e| format!("{name}: parse HLO text: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| format!("{name}: compile: {e:?}"))?;
        debug!("compiled {name} in {:.2}s", t0.elapsed().as_secs_f64());
        let e = Rc::new(Executable { info, exe });
        self.cache.insert(name.to_string(), e.clone());
        Ok(e)
    }

    /// Total state bytes a set of manifest groups would occupy — used by
    /// integration tests to validate the analytic accountant.
    pub fn group_bytes(&self, exe: &str, group: &str) -> Result<u64, String> {
        let info = self.manifest.executable(exe)?;
        Ok(info
            .inputs_in_group(group)
            .iter()
            .map(|t| t.byte_size() as u64)
            .sum())
    }
}
