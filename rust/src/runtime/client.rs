//! Backend-neutral runtime: resolves manifest executables through an
//! execution `Backend` and caches the prepared executables by name. The
//! default build carries only the pure-rust native backend; the PJRT/XLA
//! path over AOT artifacts lives behind the `xla` cargo feature.

use std::collections::HashMap;
use std::rc::Rc;

use super::backend::{Backend, BackendExec};
use super::manifest::Manifest;
use super::values::Tensor;
use crate::memory::BufferLedger;

/// A prepared executable plus its manifest metadata.
pub struct Executable {
    pub info: super::manifest::ExecutableInfo,
    exe: Rc<dyn BackendExec>,
}

impl Executable {
    /// Execute with ABI validation. Inputs must match `info.inputs` in
    /// count; outputs are the result tuple in `info.outputs` order.
    pub fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>, String> {
        if inputs.len() != self.info.inputs.len() {
            return Err(format!(
                "{}: got {} inputs, manifest wants {} (first expected: {:?})",
                self.info.name,
                inputs.len(),
                self.info.inputs.len(),
                self.info.inputs.first().map(|t| &t.name),
            ));
        }
        let outputs = self.exe.run(inputs)?;
        if outputs.len() != self.info.outputs.len() {
            return Err(format!(
                "{}: got {} outputs, manifest wants {}",
                self.info.name,
                outputs.len(),
                self.info.outputs.len()
            ));
        }
        Ok(outputs)
    }
}

/// The runtime: one backend + a prepare cache over the manifest.
pub struct Runtime {
    pub manifest: Manifest,
    pub ledger: BufferLedger,
    backend: Box<dyn Backend>,
    cache: HashMap<String, Rc<Executable>>,
}

impl Runtime {
    /// Pure-rust runtime over the generated native catalog: no artifacts,
    /// no XLA, works on a bare machine.
    pub fn native() -> Result<Self, String> {
        let (manifest, backend) = super::native::catalog();
        crate::info!(
            "runtime up: backend=native ({} executables)",
            manifest.executables.len()
        );
        Ok(Self {
            manifest,
            ledger: BufferLedger::new(),
            backend: Box::new(backend),
            cache: HashMap::new(),
        })
    }

    /// Select a backend by spec: `"native"` for the pure-rust executor,
    /// anything else is an artifacts directory for the PJRT backend.
    pub fn from_spec(spec: &str) -> Result<Self, String> {
        if spec == "native" {
            Self::native()
        } else {
            Self::new(spec)
        }
    }

    /// PJRT runtime over an AOT artifacts directory (`xla` feature).
    #[cfg(feature = "xla")]
    pub fn new(artifacts_dir: &str) -> Result<Self, String> {
        let manifest = Manifest::load(artifacts_dir)?;
        let backend = super::pjrt::PjrtBackend::new()?;
        crate::info!(
            "runtime up: backend=pjrt artifacts={} ({} executables)",
            artifacts_dir,
            manifest.executables.len()
        );
        Ok(Self {
            manifest,
            ledger: BufferLedger::new(),
            backend: Box::new(backend),
            cache: HashMap::new(),
        })
    }

    /// Without the `xla` feature the PJRT path is compiled out.
    #[cfg(not(feature = "xla"))]
    pub fn new(artifacts_dir: &str) -> Result<Self, String> {
        Err(format!(
            "artifacts runtime for {artifacts_dir:?} needs the PJRT backend, \
             which is compiled out of this build (enable with `--features \
             xla` plus the vendored xla crate); the native backend runs \
             everywhere: --backend native / Runtime::native()"
        ))
    }

    /// Prepare (cached) an executable by manifest name.
    pub fn load(&mut self, name: &str) -> Result<Rc<Executable>, String> {
        if let Some(e) = self.cache.get(name) {
            return Ok(e.clone());
        }
        let info = self.manifest.executable(name)?.clone();
        let exe = self.backend.compile(&info)?;
        let e = Rc::new(Executable { info, exe });
        self.cache.insert(name.to_string(), e.clone());
        Ok(e)
    }

    /// Which engine executes this runtime's manifest.
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Total state bytes a set of manifest groups would occupy — used by
    /// integration tests to validate the analytic accountant.
    pub fn group_bytes(&self, exe: &str, group: &str) -> Result<u64, String> {
        let info = self.manifest.executable(exe)?;
        Ok(info
            .inputs_in_group(group)
            .iter()
            .map(|t| t.byte_size() as u64)
            .sum())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_runtime_loads_and_caches() {
        let mut rt = Runtime::native().unwrap();
        assert_eq!(rt.backend_name(), "native");
        let a = rt.load("lm-tiny/init").unwrap();
        let b = rt.load("lm-tiny/init").unwrap();
        assert!(Rc::ptr_eq(&a, &b), "second load must hit the cache");
        assert!(rt.load("lm-tiny/does_not_exist").is_err());
    }

    #[test]
    fn from_spec_dispatches() {
        assert!(Runtime::from_spec("native").is_ok());
        // an artifacts path without the xla feature (or without artifacts)
        // must fail with a helpful error, not panic
        let err = match Runtime::from_spec("/definitely/not/artifacts") {
            Err(e) => e,
            Ok(_) => return, // xla build with artifacts present: fine too
        };
        assert!(!err.is_empty());
    }

    #[test]
    fn run_validates_input_arity() {
        let mut rt = Runtime::native().unwrap();
        let init = rt.load("lm-tiny/init").unwrap();
        let err = init.run(&[]).unwrap_err();
        assert!(err.contains("manifest wants"), "{err}");
    }
}
