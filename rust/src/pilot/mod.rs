//! Figure-1 pilot study, entirely in rust.
//!
//! Reproduces the paper's §2.3 experiment: a feed-forward classifier whose
//! middle (square) layer is updated by one of five rules —
//!
//!   * `Sgd`    — full-matrix SGD (upper bound);
//!   * `Lora`   — the original LoRA patch, both A and B trained (Eq. 5–6);
//!   * `LoraB`  — LoRA(B): A frozen at init, only B trained (Obs. 2.2);
//!   * `Rp`     — random projection with a FIXED matrix, Eq. (20);
//!   * `Rrp`    — resampled random projection (FLORA's key move, §2.4).
//!
//! The paper's claim, which `benches/figure1_pilot.rs` regenerates:
//! LoRA ≈ LoRA(B) ≈ RP ≪ RRP ≈ SGD in training loss.
//!
//! Gradients are hand-derived (2-hidden-layer MLP, ReLU, softmax CE) — no
//! autodiff substrate needed, and the math doubles as a check on the update
//! rules' algebra.

use crate::data::images::ImageTask;
use crate::rp;
use crate::tensor::{relu, softmax_rows, Matrix};
use crate::util::rng::Rng;

/// Which rule updates the patched middle layer W1.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Updater {
    Sgd,
    Lora,
    LoraB,
    Rp,
    Rrp,
}

impl Updater {
    pub fn name(self) -> &'static str {
        match self {
            Updater::Sgd => "SGD",
            Updater::Lora => "LoRA",
            Updater::LoraB => "LoRA(B)",
            Updater::Rp => "RP",
            Updater::Rrp => "RRP",
        }
    }

    pub fn all() -> [Updater; 5] {
        [Updater::Sgd, Updater::Lora, Updater::LoraB, Updater::Rp, Updater::Rrp]
    }
}

/// Pilot MLP: input → W0 → relu → (W1 + patch) → relu → W2 → softmax.
/// W0/W2 always train with plain SGD; W1 is the experiment's subject,
/// matching the paper ("we apply the LoRA patch to the first layer of the
/// network with a shape of 768×768" — here `hidden×hidden`).
pub struct PilotNet {
    pub w0: Matrix,        // [in, hidden]
    pub w1: Matrix,        // [hidden, hidden] — patched layer
    pub w2: Matrix,        // [hidden, classes]
    pub lora_a: Matrix,    // [rank, hidden]
    pub lora_b: Matrix,    // [hidden, rank]
    pub updater: Updater,
    pub rank: usize,
    pub lr: f32,
    /// When false, W0 is a frozen random-feature extractor. The paper's
    /// MLP is wide enough (768²) that its patched layer dominates capacity;
    /// at bench scale the surrounding layers would otherwise solve the task
    /// on their own and mask the rank effect, so the Figure-1 bench freezes
    /// W0 to keep the patched layer the bottleneck (DESIGN.md §4).
    pub train_w0: bool,
    /// When false, W2 is frozen too: the task must then be solved entirely
    /// through the patched layer, so the rank of its total update is the
    /// binding constraint — this is what makes Figure 1's separation appear
    /// at bench scale (the paper gets it from 768-dim width + 1 epoch).
    pub train_w2: bool,
    rp_seed: u64,
    step: u64,
}

impl PilotNet {
    pub fn new(
        input: usize,
        hidden: usize,
        classes: usize,
        rank: usize,
        updater: Updater,
        lr: f32,
        seed: u64,
    ) -> Self {
        let mut rng = Rng::new(seed);
        let s0 = (1.0 / input as f32).sqrt();
        let s1 = (1.0 / hidden as f32).sqrt();
        Self {
            w0: Matrix::gaussian(input, hidden, s0, &mut rng),
            w1: Matrix::gaussian(hidden, hidden, s1, &mut rng),
            w2: Matrix::gaussian(hidden, classes, s1, &mut rng),
            // LoRA init: B = 0, A ~ N(0, 1/r) (paper §2.1)
            lora_a: Matrix::gaussian(rank, hidden, (1.0 / rank as f32).sqrt(), &mut rng),
            lora_b: Matrix::zeros(hidden, rank),
            updater,
            rank,
            lr,
            train_w0: true,
            train_w2: true,
            rp_seed: seed.wrapping_add(0x5EED),
            step: 0,
        }
    }

    /// Effective middle weight: W1 (+ BA for the LoRA variants).
    fn w1_eff(&self) -> Matrix {
        match self.updater {
            Updater::Lora | Updater::LoraB => {
                &self.w1 + &self.lora_b.matmul(&self.lora_a)
            }
            _ => self.w1.clone(),
        }
    }

    /// Forward pass returning (h0, h1, probs) for backprop reuse.
    fn forward(&self, x: &Matrix) -> (Matrix, Matrix, Matrix) {
        let h0 = relu(&x.matmul(&self.w0));
        let h1 = relu(&h0.matmul(&self.w1_eff()));
        let probs = softmax_rows(&h1.matmul(&self.w2));
        (h0, h1, probs)
    }

    /// Mean cross-entropy of a batch.
    pub fn loss(&self, x: &Matrix, labels: &[usize]) -> f32 {
        let (_, _, probs) = self.forward(x);
        let mut total = 0.0;
        for (i, &y) in labels.iter().enumerate() {
            total -= (probs.at(i, y).max(1e-12)).ln();
        }
        total / labels.len() as f32
    }

    pub fn accuracy(&self, x: &Matrix, labels: &[usize]) -> f32 {
        let (_, _, probs) = self.forward(x);
        let mut hit = 0usize;
        for (i, &y) in labels.iter().enumerate() {
            let row = probs.row(i);
            let pred = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            if pred == y {
                hit += 1;
            }
        }
        hit as f32 / labels.len() as f32
    }

    /// One SGD step on a batch; returns the batch loss (pre-update).
    pub fn train_step(&mut self, x: &Matrix, labels: &[usize]) -> f32 {
        let n = labels.len() as f32;
        let (h0, h1, probs) = self.forward(x);

        // dL/dlogits = (probs - onehot)/n
        let mut dz = probs.clone();
        for (i, &y) in labels.iter().enumerate() {
            *dz.at_mut(i, y) -= 1.0;
        }
        let dz = dz.scale(1.0 / n);

        // loss before the step (reuse probs)
        let mut loss = 0.0;
        for (i, &y) in labels.iter().enumerate() {
            loss -= probs.at(i, y).max(1e-12).ln();
        }
        loss /= n;

        // backprop
        let g_w2 = h1.matmul_tn(&dz); // [hidden, classes]
        let dh1 = dz.matmul_nt(&self.w2); // [B, hidden]
        // relu'(h1): h1 > 0
        let dh1 = dh1.hadamard(&h1.map(|v| if v > 0.0 { 1.0 } else { 0.0 }));
        let g_w1 = h0.matmul_tn(&dh1); // [hidden, hidden] — ∇_W L of the patch
        let w1e = self.w1_eff();
        let dh0 = dh1.matmul_nt(&w1e);
        let dh0 = dh0.hadamard(&h0.map(|v| if v > 0.0 { 1.0 } else { 0.0 }));
        let g_w0 = x.matmul_tn(&dh0);

        // always-SGD layers (W0 optionally frozen; see field docs)
        if self.train_w0 {
            self.w0.add_scaled_inplace(&g_w0, -self.lr);
        }
        if self.train_w2 {
            self.w2.add_scaled_inplace(&g_w2, -self.lr);
        }

        // the patched layer
        match self.updater {
            Updater::Sgd => {
                self.w1.add_scaled_inplace(&g_w1, -self.lr);
            }
            Updater::Lora => {
                // Eq. (5)-(6): dA = Bᵀ G, dB = G Aᵀ — simultaneous update
                let g_a = self.lora_b.matmul_tn(&g_w1); // [r, hidden]
                let g_b = g_w1.matmul_nt(&self.lora_a); // [hidden, r]
                self.lora_a.add_scaled_inplace(&g_a, -self.lr);
                self.lora_b.add_scaled_inplace(&g_b, -self.lr);
            }
            Updater::LoraB => {
                let g_b = g_w1.matmul_nt(&self.lora_a);
                self.lora_b.add_scaled_inplace(&g_b, -self.lr);
            }
            Updater::Rp => {
                // Eq. (20) with the FIXED A₀
                let a = rp::projection(self.rp_seed, self.rank, g_w1.cols);
                let upd = rp::decompress(&rp::compress(&g_w1, &a), &a);
                self.w1.add_scaled_inplace(&upd, -self.lr);
            }
            Updater::Rrp => {
                // FLORA: fresh projection every step
                let seed = rp::param_seed(self.rp_seed, self.step as usize + 1);
                let a = rp::projection(seed, self.rank, g_w1.cols);
                let upd = rp::decompress(&rp::compress(&g_w1, &a), &a);
                self.w1.add_scaled_inplace(&upd, -self.lr);
            }
        }
        self.step += 1;
        loss
    }
}

/// A recorded training curve for one updater.
pub struct PilotCurve {
    pub updater: Updater,
    pub losses: Vec<f32>,
    pub final_train_acc: f32,
}

/// Run the full pilot: every updater on the same data stream/seed.
#[allow(clippy::too_many_arguments)]
pub fn run_pilot(
    task: &ImageTask,
    steps: usize,
    batch: usize,
    rank: usize,
    lr: f32,
    seed: u64,
    train_w0: bool,
    train_w2: bool,
) -> Vec<PilotCurve> {
    Updater::all()
        .iter()
        .map(|&u| {
            let mut net = PilotNet::new(
                task.input_dim(),
                256,
                task.classes,
                rank,
                u,
                lr,
                seed,
            );
            net.train_w0 = train_w0;
            net.train_w2 = train_w2;
            let mut data_rng = Rng::new(seed.wrapping_add(1));
            let mut losses = Vec::with_capacity(steps);
            let mut xs = Matrix::zeros(batch, task.input_dim());
            let mut ys = vec![0usize; batch];
            for _ in 0..steps {
                task.fill_batch(&mut xs, &mut ys, &mut data_rng);
                losses.push(net.train_step(&xs, &ys));
            }
            task.fill_batch(&mut xs, &mut ys, &mut data_rng);
            let final_train_acc = net.accuracy(&xs, &ys);
            PilotCurve { updater: u, losses, final_train_acc }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::images::ImageTask;

    fn task() -> ImageTask {
        ImageTask::fashion_like(10, 64, 0.3, 7)
    }

    fn final_loss(u: Updater, steps: usize) -> f32 {
        let t = task();
        let mut net = PilotNet::new(t.input_dim(), 64, t.classes, 8, u, 0.05, 3);
        let mut rng = Rng::new(4);
        let mut xs = Matrix::zeros(16, t.input_dim());
        let mut ys = vec![0usize; 16];
        let mut last = 0.0;
        for _ in 0..steps {
            t.fill_batch(&mut xs, &mut ys, &mut rng);
            last = net.train_step(&xs, &ys);
        }
        last
    }

    #[test]
    fn every_updater_decreases_loss() {
        for u in Updater::all() {
            let early = final_loss(u, 5);
            let late = final_loss(u, 120);
            assert!(
                late < early,
                "{}: early={early} late={late}",
                u.name()
            );
        }
    }

    #[test]
    fn sgd_gradients_are_correct_fd_check() {
        // finite-difference check of the hand-derived W1 gradient
        let t = task();
        let mut rng = Rng::new(5);
        let mut xs = Matrix::zeros(4, t.input_dim());
        let mut ys = vec![0usize; 4];
        t.fill_batch(&mut xs, &mut ys, &mut rng);
        let net = PilotNet::new(t.input_dim(), 32, t.classes, 4, Updater::Sgd, 0.0, 6);

        // analytic gradient via a zero-lr train step on a clone
        let mut probe = PilotNet::new(t.input_dim(), 32, t.classes, 4, Updater::Sgd, 1.0, 6);
        let w1_before = probe.w1.clone();
        probe.train_step(&xs, &ys);
        let g_analytic = &w1_before - &probe.w1; // lr=1 ⇒ g = -ΔW

        let eps = 1e-3;
        for &(i, j) in &[(0usize, 0usize), (3, 7), (13, 21)] {
            let mut plus = PilotNet::new(t.input_dim(), 32, t.classes, 4, Updater::Sgd, 0.0, 6);
            *plus.w1.at_mut(i, j) += eps;
            let mut minus = PilotNet::new(t.input_dim(), 32, t.classes, 4, Updater::Sgd, 0.0, 6);
            *minus.w1.at_mut(i, j) -= eps;
            let fd = (plus.loss(&xs, &ys) - minus.loss(&xs, &ys)) / (2.0 * eps);
            let an = g_analytic.at(i, j);
            assert!(
                (fd - an).abs() < 2e-2 * (1.0 + fd.abs().max(an.abs())),
                "({i},{j}): fd={fd} analytic={an}"
            );
        }
        let _ = net;
    }

    #[test]
    fn lora_b_stays_zero_for_a_frozen_variant() {
        // LoRA(B): A must never move
        let t = task();
        let mut net =
            PilotNet::new(t.input_dim(), 32, t.classes, 4, Updater::LoraB, 0.05, 8);
        let a0 = net.lora_a.clone();
        let mut rng = Rng::new(9);
        let mut xs = Matrix::zeros(8, t.input_dim());
        let mut ys = vec![0usize; 8];
        for _ in 0..10 {
            t.fill_batch(&mut xs, &mut ys, &mut rng);
            net.train_step(&xs, &ys);
        }
        assert!(net.lora_a.allclose(&a0, 0.0));
        assert!(net.lora_b.frobenius_norm() > 0.0);
    }

    #[test]
    fn rp_uses_fixed_projection_rrp_resamples() {
        // With zero LR on W0/W2... simpler: check W1 update direction
        // differs between two RRP steps but repeats for RP given the same
        // gradient — proxy: total W1 change after identical batches.
        let t = task();
        let mut rng = Rng::new(10);
        let mut xs = Matrix::zeros(8, t.input_dim());
        let mut ys = vec![0usize; 8];
        t.fill_batch(&mut xs, &mut ys, &mut rng);

        let mut rp1 = PilotNet::new(t.input_dim(), 32, t.classes, 4, Updater::Rp, 0.01, 11);
        let mut rp2 = PilotNet::new(t.input_dim(), 32, t.classes, 4, Updater::Rp, 0.01, 11);
        rp1.train_step(&xs, &ys);
        rp2.train_step(&xs, &ys);
        assert!(rp1.w1.allclose(&rp2.w1, 0.0), "RP is deterministic per step");

        let mut rrp = PilotNet::new(t.input_dim(), 32, t.classes, 4, Updater::Rrp, 0.01, 11);
        let w_afters: Vec<Matrix> = (0..2)
            .map(|_| {
                rrp.train_step(&xs, &ys);
                rrp.w1.clone()
            })
            .collect();
        let d1 = (&w_afters[0] - &rp1.w1).frobenius_norm();
        // second RRP step uses a different projection than the first
        let step2 = &w_afters[1] - &w_afters[0];
        let step1 = &w_afters[0] - &rp2.w1;
        let diff = (&step2 - &step1).frobenius_norm();
        assert!(diff > 1e-6 || d1 > 0.0);
    }
}
